//! Request-lifecycle tracing: per-request span trees and per-engine-step
//! timelines with per-phase timings, exported as Chrome trace-event JSON.
//!
//! The tracer is a **pure observer**: every recording method early-returns
//! on a single relaxed atomic load when tracing is disabled, so the serving
//! paths pay one branch and nothing else (`ServingConfig::enable_trace`
//! defaults to off). A temp-0 on/off property test in
//! `rust/tests/integration.rs` holds this to account: token streams, step
//! plans, and schedule counters are identical either way.
//!
//! # Model
//!
//! Two views of the same executions:
//!
//! - **Request view** (`RequestTrace`): submit → queue → first scheduled
//!   chunk → each execution span the request participated in (prefill
//!   chunk, span tile, group tile, decode step, session sync) → first
//!   token → finish/cancel. Completed requests live in a bounded ring
//!   (`trace_ring` newest, older entries dropped and counted).
//! - **Engine view** (`EngineStep`): one record per device-side execution
//!   window on the engine thread, with the participating request ids,
//!   compile bucket, lane occupancy, and a [`Phases`] breakdown (table
//!   row-gather, H2D upload, execute, logits readback, pair sync).
//!
//! # Attribution
//!
//! The engine does not know request ids; the coordinator calls
//! [`Tracer::set_context`] with the participating ids before every engine
//! call, and the engine opens/closes execution windows with
//! [`Tracer::exec_begin`] / [`Tracer::exec_end`]. Phase timings recorded
//! while no window is open (e.g. the table row-gather that precedes the
//! first span tile) accumulate as *pending* and are absorbed into the next
//! window, which is backdated by their total so the invariant
//! `sum(phases) <= span duration` holds for every emitted span.
//!
//! All writers run on the engine thread; server connection threads only
//! take the mutex briefly to snapshot for `trace.dump`, keeping the
//! buffer lock-light.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::{n, obj, s, Value};

/// What kind of execution window a span records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// One chunked-prefill execution (possibly batched across requests).
    PrefillChunk,
    /// One single-sequence span-artifact tile.
    SpanTile,
    /// One multi-sequence `[B, T]` span-group tile.
    GroupTile,
    /// One dense per-token decode execution.
    DecodeStep,
    /// A session KV readback/recompute window (pair sync).
    Sync,
    /// One speculative-decode verify execution (a scored span tile; the
    /// accept length lands as a `spec_accept` mark on the request).
    SpecVerify,
}

impl SpanKind {
    pub fn label(self) -> &'static str {
        match self {
            SpanKind::PrefillChunk => "prefill_chunk",
            SpanKind::SpanTile => "span_tile",
            SpanKind::GroupTile => "group_tile",
            SpanKind::DecodeStep => "decode_step",
            SpanKind::Sync => "sync",
            SpanKind::SpecVerify => "spec_verify",
        }
    }
}

/// Engine phase a timing sample belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Precompute-table row gather on the host.
    Gather,
    /// Host-to-device uploads (inputs, cache pairs).
    H2d,
    /// Device execution (PJRT execute).
    Exec,
    /// Device-to-host readback (logits, fresh rows).
    Readback,
    /// Full cache-pair sync readback.
    Sync,
}

/// Per-phase microsecond totals inside one execution window.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Phases {
    pub gather_us: u64,
    pub h2d_us: u64,
    pub exec_us: u64,
    pub readback_us: u64,
    pub sync_us: u64,
}

impl Phases {
    fn add(&mut self, p: Phase, us: u64) {
        match p {
            Phase::Gather => self.gather_us += us,
            Phase::H2d => self.h2d_us += us,
            Phase::Exec => self.exec_us += us,
            Phase::Readback => self.readback_us += us,
            Phase::Sync => self.sync_us += us,
        }
    }

    pub fn total_us(&self) -> u64 {
        self.gather_us + self.h2d_us + self.exec_us + self.readback_us + self.sync_us
    }

    fn is_zero(&self) -> bool {
        self.total_us() == 0
    }

    fn args(&self, out: &mut Vec<(&'static str, Value)>) {
        out.push(("gather_us", n(self.gather_us as f64)));
        out.push(("h2d_us", n(self.h2d_us as f64)));
        out.push(("exec_us", n(self.exec_us as f64)));
        out.push(("readback_us", n(self.readback_us as f64)));
        out.push(("sync_us", n(self.sync_us as f64)));
    }
}

/// One execution window as seen from a single request's span tree.
#[derive(Debug, Clone)]
pub struct ExecSpan {
    pub kind: SpanKind,
    /// Microseconds since the tracer epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Tokens this execution advanced (across all participants).
    pub tokens: u64,
    /// Compile bucket (span length T, or 0 where not applicable).
    pub bucket: u64,
    /// Active lanes for group tiles (0 where not applicable).
    pub occupancy: u64,
    pub phases: Phases,
}

/// A point event on a request's timeline (preempt, prefix hit, …).
#[derive(Debug, Clone)]
pub struct MarkRec {
    pub name: &'static str,
    pub at_us: u64,
    pub value: u64,
}

/// The full recorded lifecycle of one request.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub id: u64,
    pub submit_us: u64,
    /// Set when the request's first prefill chunk is scheduled.
    pub first_sched_us: Option<u64>,
    pub first_token_us: Option<u64>,
    pub finish_us: Option<u64>,
    pub finish_reason: Option<&'static str>,
    pub prompt_tokens: u64,
    pub generated: u64,
    pub spans: Vec<ExecSpan>,
    pub marks: Vec<MarkRec>,
}

/// One execution window as seen from the engine timeline.
#[derive(Debug, Clone)]
pub struct EngineStep {
    pub kind: SpanKind,
    pub start_us: u64,
    pub dur_us: u64,
    /// Participating request ids (empty for warmup/untracked work).
    pub ids: Vec<u64>,
    pub bucket: u64,
    pub occupancy: u64,
    pub tokens: u64,
    pub phases: Phases,
}

struct CurExec {
    kind: SpanKind,
    start_us: u64,
    bucket: u64,
    occupancy: u64,
    ids: Vec<u64>,
    phases: Phases,
}

#[derive(Default)]
struct Inner {
    live: HashMap<u64, RequestTrace>,
    done: VecDeque<RequestTrace>,
    steps: VecDeque<EngineStep>,
    globals: VecDeque<MarkRec>,
    /// Request ids participating in the next engine execution.
    ctx: Vec<u64>,
    cur: Option<CurExec>,
    /// Phase time recorded outside any execution window; absorbed (and
    /// the window backdated) by the next `exec_begin`.
    pending: Phases,
}

/// How many engine steps / global marks to retain per ring slot.
const STEPS_PER_SLOT: usize = 16;
const GLOBALS_PER_SLOT: usize = 4;

/// Lock-light lifecycle tracer. One instance per [`crate::runtime::Runtime`],
/// shared by engine, coordinator, and server handles.
pub struct Tracer {
    enabled: AtomicBool,
    ring: AtomicUsize,
    epoch: Instant,
    dropped: AtomicU64,
    inner: Mutex<Inner>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    pub fn new() -> Self {
        Tracer {
            enabled: AtomicBool::new(false),
            ring: AtomicUsize::new(256),
            epoch: Instant::now(),
            dropped: AtomicU64::new(0),
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Turn tracing on/off and set the completed-request ring capacity.
    pub fn configure(&self, enabled: bool, ring: usize) {
        self.ring.store(ring.max(1), Ordering::Relaxed);
        self.enabled.store(enabled, Ordering::Relaxed);
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Completed-request ring entries evicted so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Start a phase timer: `Some(Instant)` when tracing, `None` (free)
    /// otherwise. Pair with [`Tracer::phase_since`].
    #[inline]
    pub fn now(&self) -> Option<Instant> {
        if self.enabled() {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Record elapsed time since `t0` under phase `p`. No-op off-trace.
    #[inline]
    pub fn phase_since(&self, p: Phase, t0: Option<Instant>) {
        if let Some(t) = t0 {
            self.phase(p, t.elapsed());
        }
    }

    /// Record a phase duration into the open execution window, or into
    /// the pending pool if none is open.
    pub fn phase(&self, p: Phase, d: Duration) {
        if !self.enabled() {
            return;
        }
        let us = d.as_micros() as u64;
        let mut g = self.inner.lock().unwrap();
        match g.cur.as_mut() {
            Some(cur) => cur.phases.add(p, us),
            None => g.pending.add(p, us),
        }
    }

    // ---- request lifecycle (coordinator side) --------------------------

    pub fn req_submit(&self, id: u64, prompt_tokens: usize) {
        if !self.enabled() {
            return;
        }
        let at = self.now_us();
        let mut g = self.inner.lock().unwrap();
        g.live.insert(
            id,
            RequestTrace {
                id,
                submit_us: at,
                first_sched_us: None,
                first_token_us: None,
                finish_us: None,
                finish_reason: None,
                prompt_tokens: prompt_tokens as u64,
                generated: 0,
                spans: Vec::new(),
                marks: Vec::new(),
            },
        );
    }

    pub fn req_first_sched(&self, id: u64) {
        if !self.enabled() {
            return;
        }
        let at = self.now_us();
        let mut g = self.inner.lock().unwrap();
        if let Some(r) = g.live.get_mut(&id) {
            if r.first_sched_us.is_none() {
                r.first_sched_us = Some(at);
            }
        }
    }

    pub fn req_first_token(&self, id: u64) {
        if !self.enabled() {
            return;
        }
        let at = self.now_us();
        let mut g = self.inner.lock().unwrap();
        if let Some(r) = g.live.get_mut(&id) {
            if r.first_token_us.is_none() {
                r.first_token_us = Some(at);
            }
        }
    }

    /// Point event on one request's track (`preempt`, `prefix_hit`, …).
    pub fn req_mark(&self, id: u64, name: &'static str, value: u64) {
        if !self.enabled() {
            return;
        }
        let at = self.now_us();
        let mut g = self.inner.lock().unwrap();
        if let Some(r) = g.live.get_mut(&id) {
            r.marks.push(MarkRec { name, at_us: at, value });
        }
    }

    /// Point event on the engine track (`prefix_evict`, …).
    pub fn global_mark(&self, name: &'static str, value: u64) {
        if !self.enabled() {
            return;
        }
        let at = self.now_us();
        let mut g = self.inner.lock().unwrap();
        let cap = self.ring.load(Ordering::Relaxed) * GLOBALS_PER_SLOT;
        g.globals.push_back(MarkRec { name, at_us: at, value });
        while g.globals.len() > cap {
            g.globals.pop_front();
        }
    }

    /// Move a request from the live map into the completed ring.
    pub fn req_finish(&self, id: u64, reason: &'static str, generated: usize) {
        if !self.enabled() {
            return;
        }
        let at = self.now_us();
        let mut g = self.inner.lock().unwrap();
        let Some(mut r) = g.live.remove(&id) else {
            return;
        };
        r.finish_us = Some(at);
        r.finish_reason = Some(reason);
        r.generated = generated as u64;
        let cap = self.ring.load(Ordering::Relaxed);
        g.done.push_back(r);
        while g.done.len() > cap {
            g.done.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    // ---- execution windows (engine side) -------------------------------

    /// Set the request ids participating in subsequent engine executions.
    pub fn set_context(&self, ids: &[u64]) {
        if !self.enabled() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.ctx.clear();
        g.ctx.extend_from_slice(ids);
    }

    /// Open an execution window. Pending phase time recorded since the
    /// last window is absorbed and the start backdated by its total.
    pub fn exec_begin(&self, kind: SpanKind, bucket: usize, occupancy: usize) {
        if !self.enabled() {
            return;
        }
        let at = self.now_us();
        let mut g = self.inner.lock().unwrap();
        if g.cur.is_some() {
            // Defensive: a window left open (error path) — close it empty.
            Self::finish_exec(&mut g, &self.ring, 0, self.now_us());
        }
        let pending = std::mem::take(&mut g.pending);
        let ids = g.ctx.clone();
        g.cur = Some(CurExec {
            kind,
            start_us: at.saturating_sub(pending.total_us()),
            bucket: bucket as u64,
            occupancy: occupancy as u64,
            ids,
            phases: pending,
        });
    }

    /// Close the open execution window, crediting `tokens` advanced.
    pub fn exec_end(&self, tokens: usize) {
        if !self.enabled() {
            return;
        }
        let end_us = self.now_us();
        let mut g = self.inner.lock().unwrap();
        Self::finish_exec(&mut g, &self.ring, tokens as u64, end_us);
    }

    fn finish_exec(g: &mut Inner, ring: &AtomicUsize, tokens: u64, end_us: u64) {
        let Some(cur) = g.cur.take() else {
            return;
        };
        let dur_us = end_us.saturating_sub(cur.start_us).max(1);
        let span = ExecSpan {
            kind: cur.kind,
            start_us: cur.start_us,
            dur_us,
            tokens,
            bucket: cur.bucket,
            occupancy: cur.occupancy,
            phases: cur.phases,
        };
        for id in &cur.ids {
            if let Some(r) = g.live.get_mut(id) {
                r.spans.push(span.clone());
            }
        }
        let cap = ring.load(Ordering::Relaxed) * STEPS_PER_SLOT;
        g.steps.push_back(EngineStep {
            kind: cur.kind,
            start_us: cur.start_us,
            dur_us,
            ids: cur.ids,
            bucket: cur.bucket,
            occupancy: cur.occupancy,
            tokens,
            phases: cur.phases,
        });
        while g.steps.len() > cap {
            g.steps.pop_front();
        }
        // Phase time that belonged to this window but was recorded after
        // the execute returned is already in; anything later is pending.
    }

    // ---- snapshots -----------------------------------------------------

    /// Clone of the completed-request ring (oldest first). Test/validator
    /// surface; `trace.dump` uses [`Tracer::dump_chrome`].
    pub fn completed(&self) -> Vec<RequestTrace> {
        let g = self.inner.lock().unwrap();
        g.done.iter().cloned().collect()
    }

    pub fn live_count(&self) -> usize {
        self.inner.lock().unwrap().live.len()
    }

    pub fn completed_count(&self) -> usize {
        self.inner.lock().unwrap().done.len()
    }

    pub fn steps_count(&self) -> usize {
        self.inner.lock().unwrap().steps.len()
    }

    /// Build a Chrome trace-event JSON document (Perfetto-loadable).
    ///
    /// Track layout: `pid 1` = requests (one `tid` per request id, request
    /// + queue + execution spans and instant marks), `pid 2` = engine
    /// (`tid 1`, one complete span per execution window, args carrying
    /// ids/bucket/occupancy and the phase breakdown).
    pub fn dump_chrome(&self) -> Value {
        let g = self.inner.lock().unwrap();
        let now = self.now_us();
        let mut ev: Vec<Value> = Vec::new();
        ev.push(meta_event(1, "requests"));
        ev.push(meta_event(2, "engine"));
        for r in g.done.iter().chain(g.live.values()) {
            request_events(r, now, &mut ev);
        }
        for st in &g.steps {
            let mut args: Vec<(&'static str, Value)> = vec![
                (
                    "ids",
                    s(&st
                        .ids
                        .iter()
                        .map(|i| i.to_string())
                        .collect::<Vec<_>>()
                        .join(",")),
                ),
                ("bucket", n(st.bucket as f64)),
                ("occupancy", n(st.occupancy as f64)),
                ("tokens", n(st.tokens as f64)),
            ];
            st.phases.args(&mut args);
            ev.push(complete_event(st.kind.label(), st.start_us, st.dur_us, 2, 1, args));
        }
        for m in &g.globals {
            ev.push(instant_event(m.name, m.at_us, 2, 1, m.value));
        }
        obj(vec![
            ("traceEvents", Value::Arr(ev)),
            ("displayTimeUnit", s("ms")),
            ("dropped_requests", n(self.dropped() as f64)),
        ])
    }
}

fn meta_event(pid: u64, name: &str) -> Value {
    obj(vec![
        ("name", s("process_name")),
        ("ph", s("M")),
        ("pid", n(pid as f64)),
        ("tid", n(0.0)),
        ("args", obj(vec![("name", s(name))])),
    ])
}

fn complete_event(
    name: &str,
    ts_us: u64,
    dur_us: u64,
    pid: u64,
    tid: u64,
    args: Vec<(&'static str, Value)>,
) -> Value {
    obj(vec![
        ("name", s(name)),
        ("cat", s("firstlayer")),
        ("ph", s("X")),
        ("ts", n(ts_us as f64)),
        ("dur", n(dur_us as f64)),
        ("pid", n(pid as f64)),
        ("tid", n(tid as f64)),
        ("args", obj(args)),
    ])
}

fn instant_event(name: &str, ts_us: u64, pid: u64, tid: u64, value: u64) -> Value {
    obj(vec![
        ("name", s(name)),
        ("cat", s("firstlayer")),
        ("ph", s("i")),
        ("s", s("t")),
        ("ts", n(ts_us as f64)),
        ("pid", n(pid as f64)),
        ("tid", n(tid as f64)),
        ("args", obj(vec![("value", n(value as f64))])),
    ])
}

fn request_events(r: &RequestTrace, now_us: u64, ev: &mut Vec<Value>) {
    let end = r.finish_us.unwrap_or(now_us).max(r.submit_us + 1);
    ev.push(complete_event(
        "request",
        r.submit_us,
        end - r.submit_us,
        1,
        r.id,
        vec![
            ("id", n(r.id as f64)),
            ("prompt_tokens", n(r.prompt_tokens as f64)),
            ("generated", n(r.generated as f64)),
            ("reason", s(r.finish_reason.unwrap_or("live"))),
        ],
    ));
    if let Some(fs) = r.first_sched_us {
        ev.push(complete_event(
            "queue",
            r.submit_us,
            fs.saturating_sub(r.submit_us).max(1),
            1,
            r.id,
            vec![],
        ));
    }
    for sp in &r.spans {
        let mut args: Vec<(&'static str, Value)> = vec![
            ("tokens", n(sp.tokens as f64)),
            ("bucket", n(sp.bucket as f64)),
            ("occupancy", n(sp.occupancy as f64)),
        ];
        sp.phases.args(&mut args);
        ev.push(complete_event(sp.kind.label(), sp.start_us, sp.dur_us, 1, r.id, args));
    }
    if let Some(ft) = r.first_token_us {
        ev.push(instant_event("first_token", ft, 1, r.id, 0));
    }
    for m in &r.marks {
        ev.push(instant_event(m.name, m.at_us, 1, r.id, m.value));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn on() -> Tracer {
        let t = Tracer::new();
        t.configure(true, 8);
        t
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new(); // disabled by default
        assert!(t.now().is_none());
        t.req_submit(1, 10);
        t.set_context(&[1]);
        t.exec_begin(SpanKind::DecodeStep, 0, 0);
        t.phase(Phase::Exec, Duration::from_millis(1));
        t.exec_end(1);
        t.req_finish(1, "eos", 1);
        assert_eq!(t.completed_count(), 0);
        assert_eq!(t.live_count(), 0);
        assert_eq!(t.steps_count(), 0);
    }

    #[test]
    fn span_tree_assembly_interleaved_requests() {
        // Two requests interleave: a grouped execution advances both,
        // then each takes a solo decode step. Every span must land on
        // the right request(s) with attribution from set_context.
        let t = on();
        t.req_submit(7, 16);
        t.req_submit(9, 32);

        t.set_context(&[7, 9]);
        t.req_first_sched(7);
        t.req_first_sched(9);
        t.exec_begin(SpanKind::GroupTile, 8, 2);
        t.phase(Phase::H2d, Duration::from_micros(100));
        t.phase(Phase::Exec, Duration::from_micros(200));
        t.exec_end(16);

        t.set_context(&[7]);
        t.exec_begin(SpanKind::DecodeStep, 0, 0);
        t.phase(Phase::Exec, Duration::from_micros(50));
        t.exec_end(1);
        t.req_first_token(7);

        t.set_context(&[9]);
        t.exec_begin(SpanKind::DecodeStep, 0, 0);
        t.exec_end(1);
        t.req_first_token(9);

        t.req_finish(7, "eos", 3);
        t.req_finish(9, "max_tokens", 5);

        let done = t.completed();
        assert_eq!(done.len(), 2);
        let r7 = done.iter().find(|r| r.id == 7).unwrap();
        let r9 = done.iter().find(|r| r.id == 9).unwrap();

        // Both saw the group tile; each saw exactly one solo decode.
        assert_eq!(r7.spans.len(), 2);
        assert_eq!(r9.spans.len(), 2);
        assert_eq!(r7.spans[0].kind, SpanKind::GroupTile);
        assert_eq!(r7.spans[0].occupancy, 2);
        assert_eq!(r7.spans[0].bucket, 8);
        assert_eq!(r7.spans[0].tokens, 16);
        assert_eq!(r7.spans[1].kind, SpanKind::DecodeStep);
        assert_eq!(r9.spans[1].kind, SpanKind::DecodeStep);
        // The group tile is the same window on both trees.
        assert_eq!(r7.spans[0].start_us, r9.spans[0].start_us);
        // Lifecycle ordering: submit <= first_sched <= first_token <= finish.
        for r in [r7, r9] {
            let fs = r.first_sched_us.unwrap();
            let ft = r.first_token_us.unwrap();
            let fin = r.finish_us.unwrap();
            assert!(r.submit_us <= fs && fs <= ft && ft <= fin);
            assert!(r.finish_reason.is_some());
        }
        assert_eq!(r7.generated, 3);
        assert_eq!(r9.finish_reason, Some("max_tokens"));
        // Engine timeline saw all three windows.
        assert_eq!(t.steps_count(), 3);
    }

    #[test]
    fn pending_phases_absorbed_and_sum_bounded() {
        // A gather recorded before any window opens must be absorbed by
        // the next exec span, with sum(phases) <= dur.
        let t = on();
        t.req_submit(1, 4);
        t.set_context(&[1]);
        t.phase(Phase::Gather, Duration::from_micros(500));
        t.exec_begin(SpanKind::SpanTile, 16, 0);
        t.phase(Phase::Exec, Duration::from_micros(40));
        t.exec_end(16);
        t.req_finish(1, "eos", 1);

        let done = t.completed();
        let sp = &done[0].spans[0];
        assert_eq!(sp.phases.gather_us, 500);
        assert_eq!(sp.phases.exec_us, 40);
        assert!(
            sp.phases.total_us() <= sp.dur_us,
            "phases {} > dur {}",
            sp.phases.total_us(),
            sp.dur_us
        );
        // A second exec must not inherit the already-absorbed gather.
        assert!(!sp.phases.is_zero());
    }

    #[test]
    fn ring_evicts_oldest_and_counts_drops() {
        let t = Tracer::new();
        t.configure(true, 3);
        for id in 0..5u64 {
            t.req_submit(id, 1);
            t.req_finish(id, "eos", 0);
        }
        assert_eq!(t.completed_count(), 3);
        assert_eq!(t.dropped(), 2);
        let ids: Vec<u64> = t.completed().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3, 4]);
    }

    #[test]
    fn chrome_dump_roundtrips_and_has_complete_chains() {
        let t = on();
        t.req_submit(3, 8);
        t.set_context(&[3]);
        t.req_first_sched(3);
        t.exec_begin(SpanKind::PrefillChunk, 0, 0);
        t.phase(Phase::Exec, Duration::from_micros(10));
        t.exec_end(8);
        t.req_first_token(3);
        t.req_mark(3, "prefix_hit", 4);
        t.exec_begin(SpanKind::DecodeStep, 0, 0);
        t.exec_end(1);
        t.req_finish(3, "eos", 2);
        t.global_mark("prefix_evict", 2);

        let dump = t.dump_chrome();
        // Round-trip through the serializer/parser.
        let text = json::to_string(&dump);
        let back = json::parse(&text).unwrap();
        let evs = back.get_opt("traceEvents").and_then(|v| v.as_arr()).unwrap();
        // Two process_name metas + request + queue + 2 request-side spans
        // + first_token + mark + 2 engine steps + 1 global mark.
        assert!(evs.len() >= 10, "got {} events", evs.len());
        let names: Vec<&str> = evs.iter().filter_map(|e| e.str_field("name").ok()).collect();
        for want in [
            "process_name",
            "request",
            "queue",
            "prefill_chunk",
            "decode_step",
            "first_token",
            "prefix_hit",
            "prefix_evict",
        ] {
            assert!(names.contains(&want), "missing event {want}");
        }
        // Every complete event nests inside its request span and phases
        // sum within the duration.
        let req = evs
            .iter()
            .find(|e| e.str_field("name").ok() == Some("request"))
            .unwrap();
        let rts = req.get_opt("ts").and_then(|v| v.as_u64()).unwrap();
        let rdur = req.get_opt("dur").and_then(|v| v.as_u64()).unwrap();
        for e in evs {
            if e.str_field("ph").ok() != Some("X")
                || e.str_field("name").ok() == Some("request")
            {
                continue;
            }
            let pid = e.get_opt("pid").and_then(|v| v.as_u64()).unwrap();
            if pid != 1 {
                continue;
            }
            let ts = e.get_opt("ts").and_then(|v| v.as_u64()).unwrap();
            let dur = e.get_opt("dur").and_then(|v| v.as_u64()).unwrap();
            assert!(ts >= rts && ts + dur <= rts + rdur, "span outside request window");
            if let Some(args) = e.get_opt("args") {
                let phase_sum: u64 = ["gather_us", "h2d_us", "exec_us", "readback_us", "sync_us"]
                    .iter()
                    .filter_map(|k| args.get_opt(k).and_then(|v| v.as_u64()))
                    .sum();
                assert!(phase_sum <= dur, "phases {phase_sum} > dur {dur}");
            }
        }
    }

    #[test]
    fn exec_without_context_hits_engine_track_only() {
        let t = on();
        t.req_submit(1, 4);
        t.set_context(&[]);
        t.exec_begin(SpanKind::DecodeStep, 0, 0);
        t.exec_end(1);
        t.req_finish(1, "eos", 0);
        assert_eq!(t.completed()[0].spans.len(), 0);
        assert_eq!(t.steps_count(), 1);
    }
}
