//! Human-readable formatting for the paper-table printers.

/// `184549376` -> `"184,549,376"` (the paper's thousands style).
pub fn commas(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let digits = s.as_bytes();
    for (i, d) in digits.iter().enumerate() {
        if i > 0 && (digits.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*d as char);
    }
    out
}

/// Signed variant for memory deltas.
pub fn commas_i(n: i64) -> String {
    if n < 0 {
        format!("-{}", commas(n.unsigned_abs()))
    } else {
        commas(n as u64)
    }
}

/// `6927000000` -> `"6.9B"`, `7000000` -> `"7.0M"`.
pub fn human_count(n: u64) -> String {
    let f = n as f64;
    if f >= 1e9 {
        format!("{:.1}B", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.1}M", f / 1e6)
    } else if f >= 1e3 {
        format!("{:.1}K", f / 1e3)
    } else {
        n.to_string()
    }
}

/// Reduction factor in the paper's style: rounded to integer, with commas:
/// `11264.3` -> `"11,264x"`.
pub fn factor(x: f64) -> String {
    format!("{}x", commas(x.round() as u64))
}

/// Bytes -> MiB/GiB string.
pub fn bytes(n: u64) -> String {
    let f = n as f64;
    if f >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} GiB", f / (1024.0 * 1024.0 * 1024.0))
    } else if f >= 1024.0 * 1024.0 {
        format!("{:.2} MiB", f / (1024.0 * 1024.0))
    } else if f >= 1024.0 {
        format!("{:.2} KiB", f / 1024.0)
    } else {
        format!("{n} B")
    }
}

/// Fixed-width right-aligned cell.
pub fn cell(s: &str, width: usize) -> String {
    format!("{s:>width$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commas_basic() {
        assert_eq!(commas(0), "0");
        assert_eq!(commas(999), "999");
        assert_eq!(commas(1000), "1,000");
        assert_eq!(commas(184549376), "184,549,376");
    }

    #[test]
    fn commas_signed() {
        assert_eq!(commas_i(-1237843968), "-1,237,843,968");
        assert_eq!(commas_i(434765824), "434,765,824");
    }

    #[test]
    fn human() {
        assert_eq!(human_count(6_900_000_000), "6.9B");
        assert_eq!(human_count(46_700_000_000), "46.7B");
        assert_eq!(human_count(512), "512");
    }

    #[test]
    fn factor_style() {
        assert_eq!(factor(11264.0), "11,264x");
        assert_eq!(factor(2.6), "3x");
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2 * 1024 * 1024), "2.00 MiB");
    }
}
