//! Minimal JSON parser + writer.
//!
//! Serde is unavailable in the offline build, and the only JSON we touch is
//! the AOT manifest (read) plus server request/response bodies (read/write),
//! so a small recursive-descent parser over a DOM `Value` is the right
//! size.  Supports the full JSON grammar except `\u` surrogate pairs are
//! passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON document.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `Error::Manifest` if missing (manifest-centric).
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()
            .and_then(|o| o.get(key))
            .ok_or_else(|| Error::Manifest(format!("missing field `{key}`")))
    }
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.get(key)?
            .as_str()
            .ok_or_else(|| Error::Manifest(format!("field `{key}` is not a string")))
    }
    pub fn u64_field(&self, key: &str) -> Result<u64> {
        self.get(key)?
            .as_u64()
            .ok_or_else(|| Error::Manifest(format!("field `{key}` is not a number")))
    }
}

pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().ok_or_else(|| self.err("unexpected EOF"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                // Multi-byte UTF-8: copy raw bytes through.
                b if b < 0x80 => s.push(b as char),
                b => {
                    // Reconstruct the UTF-8 sequence.
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let chunk = &self.bytes[start..self.pos];
                    s.push_str(std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

/// Serialize a `Value` back to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(&mut out, v);
    out
}

fn write_value(out: &mut String, v: &Value) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_string(out, s),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, item);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(out, k);
                out.push(':');
                write_value(out, val);
            }
            out.push('}');
        }
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders used by the server.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

pub fn n(v: f64) -> Value {
    Value::Num(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" -12.5e2 ").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].str_field("b").unwrap(), "x");
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(parse("\"héllo→\"").unwrap(), Value::Str("héllo→".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":false,"o":{"k":null}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn errors_carry_offset() {
        match parse("[1, ") {
            Err(Error::Json { offset, .. }) => assert!(offset >= 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(Default::default()));
    }
}
