//! Small substrates built in-tree (the offline build has no serde/rand/
//! criterion): JSON, deterministic RNG, formatting, timing.

pub mod fmt;
pub mod json;
pub mod rng;
pub mod timer;
