//! Deterministic PRNG (SplitMix64 + xoshiro256**) for workload generation,
//! sampling and the in-tree property-test harness.  No `rand` crate in the
//! offline build; this is the standard Blackman/Vigna construction.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Rejection-free Lemire reduction.
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Exponential inter-arrival sample with the given rate (per second).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[1.0, 1.0, 8.0])] += 1;
        }
        assert!(counts[2] > counts[0] * 4);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
