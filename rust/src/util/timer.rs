//! Timing helpers for the in-tree bench harness (criterion is unavailable
//! offline; `rust/benches/*.rs` use `harness = false` binaries built on
//! these primitives).

use std::time::{Duration, Instant};

/// Statistics over repeated timed runs.
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        Stats {
            n,
            mean: total / n as u32,
            p50: samples[n / 2],
            p95: samples[(n * 95 / 100).min(n - 1)],
            min: samples[0],
            max: samples[n - 1],
        }
    }

    pub fn per_sec(&self, items_per_run: usize) -> f64 {
        items_per_run as f64 / self.mean.as_secs_f64()
    }
}

/// Run `f` for `warmup` + `iters` iterations and collect timing stats.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    Stats::from_samples(samples)
}

/// Time a single closure.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

/// Pretty-print a bench row (name, stats, optional throughput).
pub fn report(name: &str, stats: &Stats, throughput: Option<(f64, &str)>) {
    let tp = throughput
        .map(|(v, unit)| format!("  {v:>12.1} {unit}"))
        .unwrap_or_default();
    println!(
        "{name:<44} mean {:>9.1?}  p50 {:>9.1?}  p95 {:>9.1?}  (n={}){tp}",
        stats.mean, stats.p50, stats.p95, stats.n
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(vec![
            Duration::from_micros(10),
            Duration::from_micros(30),
            Duration::from_micros(20),
        ]);
        assert_eq!(s.min, Duration::from_micros(10));
        assert_eq!(s.max, Duration::from_micros(30));
        assert_eq!(s.p50, Duration::from_micros(20));
        assert_eq!(s.n, 3);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }
}
