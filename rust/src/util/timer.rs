//! Timing helpers for the in-tree bench harness (criterion is unavailable
//! offline; `rust/benches/*.rs` use `harness = false` binaries built on
//! these primitives).

use std::time::{Duration, Instant};

/// Statistics over repeated timed runs.
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        Stats {
            n,
            mean: total / n as u32,
            p50: samples[n / 2],
            p95: samples[(n * 95 / 100).min(n - 1)],
            p99: samples[(n * 99 / 100).min(n - 1)],
            min: samples[0],
            max: samples[n - 1],
        }
    }

    pub fn per_sec(&self, items_per_run: usize) -> f64 {
        items_per_run as f64 / self.mean.as_secs_f64()
    }
}

/// Run `f` for `warmup` + `iters` iterations and collect timing stats.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    Stats::from_samples(samples)
}

/// Time a single closure.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

/// Emit one machine-readable bench result as a `BENCHJSON {...}` stdout
/// line.  `scripts/bench_gate.sh` collects these lines from the bench
/// binaries into `BENCH_engine.json`, so the perf trajectory is recorded
/// run over run.  Non-finite values are clamped to 0 to keep the output
/// valid JSON.
pub fn emit_json(bench: &str, fields: &[(&str, f64)]) {
    use std::fmt::Write;
    let mut s = format!("BENCHJSON {{\"bench\":\"{bench}\"");
    for (k, v) in fields {
        let v = if v.is_finite() { *v } else { 0.0 };
        let _ = write!(s, ",\"{k}\":{v}");
    }
    s.push('}');
    println!("{s}");
}

/// Pretty-print a bench row (name, stats, optional throughput).
pub fn report(name: &str, stats: &Stats, throughput: Option<(f64, &str)>) {
    let tp = throughput
        .map(|(v, unit)| format!("  {v:>12.1} {unit}"))
        .unwrap_or_default();
    println!(
        "{name:<44} mean {:>9.1?}  p50 {:>9.1?}  p95 {:>9.1?}  p99 {:>9.1?}  (n={}){tp}",
        stats.mean, stats.p50, stats.p95, stats.p99, stats.n
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(vec![
            Duration::from_micros(10),
            Duration::from_micros(30),
            Duration::from_micros(20),
        ]);
        assert_eq!(s.min, Duration::from_micros(10));
        assert_eq!(s.max, Duration::from_micros(30));
        assert_eq!(s.p50, Duration::from_micros(20));
        assert_eq!(s.p99, Duration::from_micros(30));
        assert!(s.p99 >= s.p95 && s.p95 >= s.p50);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn emit_json_is_line_safe() {
        // Smoke: must not panic on non-finite values (clamped to 0).
        emit_json("t", &[("a", 1.5), ("b", f64::NAN), ("c", f64::INFINITY)]);
    }
}
