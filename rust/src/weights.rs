//! `.fw` tensor-bag loader (written by `python/compile/params.py`).
//!
//! Format, little-endian:
//! ```text
//! magic b"FLW1" | u32 n | n x ( u32 name_len, name,
//!     u32 ndim, u64 dims[ndim], u32 dtype, u64 nbytes, data )
//! ```

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use crate::error::{Error, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorDType {
    F32,
    I32,
}

/// A host tensor loaded from disk.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub dtype: TensorDType,
    /// Raw little-endian data (4 bytes/elem).
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        if self.dtype != TensorDType::F32 {
            return Err(Error::Weights(format!("{}: not f32", self.name)));
        }
        // Data is 4-aligned because Vec<u8> from read has arbitrary
        // alignment; copy-free view requires alignment, so check.
        let (pre, mid, post) = unsafe { self.data.align_to::<f32>() };
        if !pre.is_empty() || !post.is_empty() {
            return Err(Error::Weights(format!("{}: misaligned data", self.name)));
        }
        Ok(mid)
    }

    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        if self.dtype != TensorDType::F32 {
            return Err(Error::Weights(format!("{}: not f32", self.name)));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// An ordered bag of named tensors.
#[derive(Debug, Clone)]
pub struct WeightsFile {
    pub order: Vec<String>,
    pub tensors: BTreeMap<String, Tensor>,
}

impl WeightsFile {
    pub fn load(path: impl AsRef<Path>) -> Result<WeightsFile> {
        let path = path.as_ref();
        let mut f = std::fs::File::open(path)
            .map_err(|e| Error::Weights(format!("{}: {e}", path.display())))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"FLW1" {
            return Err(Error::Weights(format!("{}: bad magic", path.display())));
        }
        let n = read_u32(&mut f)? as usize;
        let mut order = Vec::with_capacity(n);
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name_len = read_u32(&mut f)? as usize;
            if name_len > 4096 {
                return Err(Error::Weights("absurd name length".into()));
            }
            let mut name_buf = vec![0u8; name_len];
            f.read_exact(&mut name_buf)?;
            let name = String::from_utf8(name_buf)
                .map_err(|_| Error::Weights("non-utf8 tensor name".into()))?;
            let ndim = read_u32(&mut f)? as usize;
            if ndim > 8 {
                return Err(Error::Weights(format!("{name}: ndim {ndim} > 8")));
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u64(&mut f)? as usize);
            }
            let dtype = match read_u32(&mut f)? {
                0 => TensorDType::F32,
                1 => TensorDType::I32,
                other => {
                    return Err(Error::Weights(format!("{name}: dtype {other}")));
                }
            };
            let nbytes = read_u64(&mut f)? as usize;
            let expect = dims.iter().product::<usize>() * 4;
            if nbytes != expect {
                return Err(Error::Weights(format!(
                    "{name}: payload {nbytes} != dims product {expect}"
                )));
            }
            // Over-allocate to guarantee 4-byte alignment of the payload.
            let mut data = vec![0u8; nbytes];
            f.read_exact(&mut data)?;
            order.push(name.clone());
            tensors.insert(
                name.clone(),
                Tensor {
                    name,
                    dims,
                    dtype,
                    data,
                },
            );
        }
        Ok(WeightsFile { order, tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| Error::Weights(format!("missing tensor `{name}`")))
    }

    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|t| t.elems()).sum()
    }
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn sample_file(path: &Path) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"FLW1").unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        // tensor "a": f32 [2,3]
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(b"a").unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&2u64.to_le_bytes()).unwrap();
        f.write_all(&3u64.to_le_bytes()).unwrap();
        f.write_all(&0u32.to_le_bytes()).unwrap();
        f.write_all(&24u64.to_le_bytes()).unwrap();
        for i in 0..6 {
            f.write_all(&(i as f32).to_le_bytes()).unwrap();
        }
        // tensor "b": i32 [1]
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(b"b").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&1u64.to_le_bytes()).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&4u64.to_le_bytes()).unwrap();
        f.write_all(&7i32.to_le_bytes()).unwrap();
    }

    #[test]
    fn roundtrip() {
        let p = std::env::temp_dir().join("fl_weights_test.fw");
        sample_file(&p);
        let w = WeightsFile::load(&p).unwrap();
        assert_eq!(w.order, vec!["a", "b"]);
        let a = w.get("a").unwrap();
        assert_eq!(a.dims, vec![2, 3]);
        assert_eq!(a.to_f32_vec().unwrap(), vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(w.total_params(), 7);
    }

    #[test]
    fn bad_magic_rejected() {
        let p = std::env::temp_dir().join("fl_weights_bad.fw");
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(WeightsFile::load(&p).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let p = std::env::temp_dir().join("fl_weights_trunc.fw");
        sample_file(&p);
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..data.len() - 3]).unwrap();
        assert!(WeightsFile::load(&p).is_err());
    }
}
