//! Docs gate (artifact-free): every path-like reference in
//! `ARCHITECTURE.md` and `docs/*.md` must point at a real file or
//! directory in the repo, so the documentation cannot silently rot as
//! code moves.  Run together with `cargo doc --no-deps` via
//! `scripts/docs_gate.sh`.

use std::path::PathBuf;

fn repo_root() -> PathBuf {
    // CARGO_MANIFEST_DIR is rust/; the docs live one level up.
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .to_path_buf()
}

/// Whether a backtick-quoted token looks like a repo path (vs. a code
/// identifier, flag, or JSON snippet).
fn looks_like_repo_path(tok: &str) -> bool {
    let prefixed = ["rust/", "python/", "docs/", "examples/", "scripts/"]
        .iter()
        .any(|p| tok.starts_with(p));
    let root_md = !tok.contains('/') && tok.ends_with(".md");
    (prefixed || root_md)
        && !tok.contains(' ')
        && !tok.contains('*')
        && !tok.contains('`')
}

#[test]
fn doc_file_references_resolve() {
    let root = repo_root();
    let mut doc_files = vec![root.join("ARCHITECTURE.md")];
    let docs_dir = root.join("docs");
    for entry in std::fs::read_dir(&docs_dir).expect("docs/ directory missing") {
        let p = entry.unwrap().path();
        if p.extension().and_then(|e| e.to_str()) == Some("md") {
            doc_files.push(p);
        }
    }
    assert!(doc_files.len() >= 3, "expected ARCHITECTURE.md + docs/*.md");

    let mut checked = 0usize;
    let mut missing = Vec::new();
    for f in &doc_files {
        let text = std::fs::read_to_string(f)
            .unwrap_or_else(|e| panic!("{}: {e}", f.display()));
        // Inline code spans alternate with prose when splitting on '`'.
        for tok in text.split('`').skip(1).step_by(2) {
            let clean = tok.trim_end_matches('/');
            if !looks_like_repo_path(clean) {
                continue;
            }
            checked += 1;
            if !root.join(clean).exists() {
                missing.push(format!(
                    "{}: `{tok}`",
                    f.file_name().unwrap().to_string_lossy()
                ));
            }
        }
    }
    assert!(
        checked >= 15,
        "only {checked} path references found — did the match pattern rot?"
    );
    assert!(
        missing.is_empty(),
        "dangling doc references:\n{}",
        missing.join("\n")
    );
}

/// The protocol doc and the server module doc must agree on the event
/// vocabulary (the drift this PR fixed must stay fixed) — including the
/// v2 conversation events.
#[test]
fn protocol_doc_covers_server_events() {
    let root = repo_root();
    let proto = std::fs::read_to_string(root.join("docs/protocol.md")).unwrap();
    let server = std::fs::read_to_string(root.join("rust/src/server/mod.rs")).unwrap();
    for ev in [
        "token",
        "done",
        "rejected",
        "metrics",
        "traffic",
        "ok",
        "pong",
        "error",
        "chat.opened",
        "chat.closed",
        "trace",
        "prom",
        "metrics.delta",
        "metrics.end",
    ] {
        let lit = format!("\"event\":\"{ev}\"");
        let emitted = format!("s(\"{ev}\")");
        assert!(
            proto.contains(&format!("`{ev}`")) || proto.contains(&lit),
            "docs/protocol.md does not document event `{ev}`"
        );
        assert!(
            server.contains(&emitted),
            "server/mod.rs no longer emits event `{ev}` — update docs/protocol.md"
        );
    }
}

/// The overload front door's wire vocabulary — the tenant field, the
/// shed classification on `rejected`, and the split counters — must be
/// documented in the protocol doc AND actually present in the server
/// source, so neither side can drift.
#[test]
fn protocol_doc_covers_overload_vocabulary() {
    let root = repo_root();
    let proto = std::fs::read_to_string(root.join("docs/protocol.md")).unwrap();
    let server = std::fs::read_to_string(root.join("rust/src/server/mod.rs")).unwrap();
    for word in [
        "tenant",
        "retry_after_ms",
        "requests_shed",
        "shed_ladder_level",
    ] {
        assert!(
            proto.contains(&format!("`{word}`")),
            "docs/protocol.md does not document `{word}`"
        );
        assert!(
            server.contains(word),
            "server/mod.rs no longer references `{word}` — update docs/protocol.md"
        );
    }
    // The two rejection classes are spelled out as reason values.
    for reason in ["\"rejected\"", "\"shed\""] {
        assert!(
            proto.contains(reason),
            "docs/protocol.md does not spell out reason {reason}"
        );
    }
}
