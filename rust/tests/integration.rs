//! Integration tests over the real AOT artifacts (E4/E5/E6 rust side).
//!
//! These need `make artifacts` to have run; they are skipped (cleanly)
//! when the bundle is missing so `cargo test` works on a fresh checkout.

use std::sync::Arc;

use firstlayer::config::ServingConfig;
use firstlayer::coordinator::sampling::SamplingParams;
use firstlayer::coordinator::{Coordinator, GenRequest};
use firstlayer::manifest::Manifest;
use firstlayer::runtime::{CacheBatch, ModelEngine, Runtime, StepPath};
use firstlayer::scheduler::Priority;
use firstlayer::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

fn engine(dir: &std::path::Path, model: &str) -> (Runtime, ModelEngine) {
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(dir).unwrap();
    let e = ModelEngine::load(&rt, &manifest, model).unwrap();
    (rt, e)
}

fn serving(dir: &std::path::Path, model: &str, precompute: bool) -> ServingConfig {
    ServingConfig {
        artifacts_dir: dir.to_string_lossy().into_owned(),
        model: model.to_string(),
        use_precompute: precompute,
        ..Default::default()
    }
}

/// E4/E5: engine-level equivalence — logits argmax and the written KV rows
/// agree between the two paths across random batches and positions.
#[test]
fn decode_paths_equivalent_all_models() {
    let dir = require_artifacts!();
    for model in ["tiny-serial", "tiny-parallel", "tiny-moe", "tiny-moe-parallel"] {
        let (_rt, eng) = engine(&dir, model);
        let cfg = eng.config().clone();
        let mut rng = Rng::new(42);
        for n in [1usize, 2] {
            let bucket = eng.decode_bucket(n, StepPath::Baseline).unwrap();
            let mut caches = CacheBatch::zeros(
                cfg.n_layers,
                bucket,
                cfg.max_seq,
                cfg.n_kv_heads,
                cfg.head_dim(),
            );
            // Random (but shared) cache contents + positions.
            for x in caches.k.iter_mut().chain(caches.v.iter_mut()) {
                *x = (rng.f64() as f32) - 0.5;
            }
            let tokens: Vec<u32> = (0..n)
                .map(|_| rng.below(cfg.vocab_size as u64) as u32)
                .collect();
            let pos: Vec<u32> = (0..n).map(|_| rng.below(20) as u32 + 1).collect();
            let base = eng
                .decode(StepPath::Baseline, &tokens, &pos, &caches)
                .unwrap();
            let pre = eng
                .decode(StepPath::Precompute, &tokens, &pos, &caches)
                .unwrap();
            let v = cfg.vocab_size;
            for i in 0..n {
                let lb = &base.logits[i * v..(i + 1) * v];
                let lp = &pre.logits[i * v..(i + 1) * v];
                let max_diff = lb
                    .iter()
                    .zip(lp)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                assert!(max_diff < 1e-3, "{model} n={n} seq {i}: diff {max_diff}");
            }
            let kdiff = base
                .new_k
                .iter()
                .zip(&pre.new_k)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(kdiff < 1e-3, "{model}: new K rows diverge ({kdiff})");
        }
    }
}

/// The ablation artifact (in-graph Pallas gather) agrees too.
#[test]
fn gather_ablation_equivalent() {
    let dir = require_artifacts!();
    let (_rt, eng) = engine(&dir, "tiny-serial");
    let cfg = eng.config().clone();
    let n = 3;
    let bucket = eng.decode_bucket(n, StepPath::PrecomputeGather).unwrap();
    let caches = CacheBatch::zeros(
        cfg.n_layers,
        bucket,
        cfg.max_seq,
        cfg.n_kv_heads,
        cfg.head_dim(),
    );
    let tokens = [7u32, 400, 3];
    let pos = [0u32, 0, 0];
    let a = eng
        .decode(StepPath::Precompute, &tokens, &pos, &caches)
        .unwrap();
    let b = eng
        .decode(StepPath::PrecomputeGather, &tokens, &pos, &caches)
        .unwrap();
    let diff = a
        .logits
        .iter()
        .zip(&b.logits)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(diff < 1e-4, "gather ablation diverges: {diff}");
}

/// E6: full coordinator runs produce identical greedy outputs on both paths.
#[test]
fn coordinator_greedy_outputs_identical() {
    let dir = require_artifacts!();
    let prompts = [
        "the quick brown fox",
        "attention is",
        "memory bandwidth limits",
        "a",
    ];
    let mut outputs: Vec<Vec<Vec<u32>>> = Vec::new();
    for precompute in [false, true] {
        let cfg = serving(&dir, "tiny-serial", precompute);
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let ids: Vec<u64> = prompts
            .iter()
            .map(|p| c.submit_text(p, 12, SamplingParams::default()).unwrap())
            .collect();
        c.run_to_completion(10_000).unwrap();
        outputs.push(
            ids.iter()
                .map(|id| c.generated(*id).unwrap().to_vec())
                .collect(),
        );
    }
    assert_eq!(
        outputs[0], outputs[1],
        "baseline vs precompute greedy outputs diverge"
    );
}

/// Decode after prefill must be position-consistent: generating one token
/// at a time from a 1-token prompt equals the coordinator's own output.
#[test]
fn coordinator_deterministic_across_runs() {
    let dir = require_artifacts!();
    let cfg = serving(&dir, "tiny-parallel", true);
    let mut outs = Vec::new();
    for _ in 0..2 {
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let id = c.submit_text("the scheduler admits", 10, SamplingParams::default()).unwrap();
        c.run_to_completion(10_000).unwrap();
        outs.push(c.generated(id).unwrap().to_vec());
    }
    assert_eq!(outs[0], outs[1]);
}

/// Chunked prefill must be token-identical to monolithic prefill at
/// temperature 0: splitting a prompt into table-gather + decode-kernel
/// spans changes the compute schedule, never the math.
#[test]
fn chunked_prefill_matches_monolithic() {
    let dir = require_artifacts!();
    let prompts: Vec<Vec<u32>> = vec![
        vec![3; 24],
        vec![11; 17],
        (0..21).map(|i| (i * 7 % 500) as u32).collect(),
        vec![2], // single-token prompt: first chunk is also the last
    ];
    let mut outs: Vec<Vec<Vec<u32>>> = Vec::new();
    for chunk in [0usize, 8] {
        let mut cfg = serving(&dir, "tiny-serial", true);
        cfg.prefill_chunk_tokens = chunk;
        cfg.step_token_budget = if chunk == 0 { 0 } else { 16 };
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let ids: Vec<u64> = prompts
            .iter()
            .map(|p| {
                c.submit(GenRequest {
                    prompt: p.clone(),
                    max_new_tokens: 10,
                    priority: Priority::Normal,
                    params: SamplingParams::default(),
                })
                .unwrap()
            })
            .collect();
        c.run_to_completion(50_000).unwrap();
        if chunk > 0 {
            // The 24/17/21-token prompts cannot fit one 8-token chunk.
            let chunks = c
                .metrics
                .prefill_chunks
                .load(std::sync::atomic::Ordering::Relaxed);
            assert!(chunks > 4, "expected chunked execution, got {chunks}");
        }
        outs.push(
            ids.iter()
                .map(|id| c.generated(*id).unwrap().to_vec())
                .collect(),
        );
    }
    assert_eq!(
        outs[0], outs[1],
        "chunked prefill diverges from monolithic at temperature 0"
    );
}

/// Cross-request prefix cache: two requests sharing a long system prompt
/// produce token-identical output at temperature 0 with the cache on vs
/// off, and the second request executes strictly fewer prefill tokens
/// (the cached span is forked, not recomputed — neither attention nor
/// the first-layer table gather run for it).
#[test]
fn prefix_cache_reuses_shared_system_prompt() {
    let dir = require_artifacts!();
    // 24-token shared "system prompt" (3 full 8-token KV blocks are
    // cacheable) + distinct short user suffixes; prompts stay under the
    // tiny models' 32-token prefill bucket.
    let system: Vec<u32> = (0..24).map(|i| (i * 13 % 500) as u32).collect();
    let mk = |suffix: &[u32]| {
        let mut p = system.clone();
        p.extend_from_slice(suffix);
        p
    };
    let prompts = [mk(&[7, 9, 11]), mk(&[401, 3, 77, 12])];
    let mut outs: Vec<Vec<Vec<u32>>> = Vec::new();
    let mut prefill_tokens_per_req: Vec<Vec<u64>> = Vec::new();
    for enable in [false, true] {
        let mut cfg = serving(&dir, "tiny-serial", true);
        cfg.enable_prefix_cache = enable;
        cfg.kv_block_tokens = 8;
        cfg.prefill_chunk_tokens = 8;
        cfg.step_token_budget = 16;
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let mut per_req = Vec::new();
        let mut ids = Vec::new();
        // Sequentially: the first request must be finished (and inserted
        // into the cache) before the second submits and matches.
        for p in &prompts {
            let before = c.engine().traffic.snapshot().prefill_tokens;
            let id = c
                .submit(GenRequest {
                    prompt: p.clone(),
                    max_new_tokens: 8,
                    priority: Priority::Normal,
                    params: SamplingParams::default(),
                })
                .unwrap();
            c.run_to_completion(50_000).unwrap();
            per_req.push(c.engine().traffic.snapshot().prefill_tokens - before);
            ids.push(id);
        }
        if enable {
            use std::sync::atomic::Ordering::Relaxed;
            assert!(c.metrics.prefix_hits.load(Relaxed) >= 1, "no cache hit");
            assert_eq!(
                c.metrics.prefix_cached_tokens.load(Relaxed),
                24,
                "second request should reuse the system prompt's 3 blocks"
            );
            assert!(c.prefix_cache_blocks_held() > 0);
        }
        outs.push(
            ids.iter()
                .map(|id| c.generated(*id).unwrap().to_vec())
                .collect(),
        );
        prefill_tokens_per_req.push(per_req);
    }
    assert_eq!(
        outs[0], outs[1],
        "prefix cache changed temperature-0 output"
    );
    // Cache off: both requests prefill their whole prompt.  Cache on:
    // the first (cold) does too, the second prefills only its suffix.
    assert_eq!(prefill_tokens_per_req[0][1], prompts[1].len() as u64);
    assert_eq!(prefill_tokens_per_req[1][0], prompts[0].len() as u64);
    assert!(
        prefill_tokens_per_req[1][1] < prefill_tokens_per_req[0][1],
        "cache hit did not reduce executed prefill tokens \
         ({} vs {})",
        prefill_tokens_per_req[1][1],
        prefill_tokens_per_req[0][1]
    );
    assert_eq!(
        prefill_tokens_per_req[1][1],
        (prompts[1].len() - 24) as u64,
        "second request should prefill exactly the uncached suffix"
    );
}

/// Device-resident KV: a span chained through one `DeviceCacheSession`
/// uploads the cache pair exactly ONCE (the acceptance criterion the
/// transfer counters make measurable), where the host path uploads it
/// once per token — and the two paths produce bit-identical logits and
/// K/V rows (same kernels, same inputs; chaining only changes where the
/// bytes live between steps).
#[test]
fn device_span_uploads_cache_once_and_matches_host() {
    let dir = require_artifacts!();
    let (_rt, eng) = engine(&dir, "tiny-serial");
    let cfg = eng.config().clone();
    let bucket = eng.decode_bucket(1, StepPath::Precompute).unwrap();
    let mk_caches = || {
        CacheBatch::zeros(
            cfg.n_layers,
            bucket,
            cfg.max_seq,
            cfg.n_kv_heads,
            cfg.head_dim(),
        )
    };
    let span: Vec<u32> = (0..6u32).map(|i| (i * 31) % cfg.vocab_size as u32).collect();
    let pair_bytes =
        2 * (cfg.n_layers * bucket * cfg.max_seq * cfg.n_kv_heads * cfg.head_dim()) as u64 * 4;

    eng.set_device_kv(true);
    let stats = eng.transfers();
    let before = stats.snapshot();
    let mut dev_caches = mk_caches();
    let dev = eng
        .decode_span(StepPath::Precompute, &span, 0, &mut dev_caches)
        .unwrap();
    let d = stats.snapshot().since(&before);
    if eng.device_kv_active() {
        assert_eq!(d.cache_uploads, 1, "device span must upload the pair once");
        assert_eq!(d.cache_h2d_bytes, pair_bytes);
        assert_eq!(d.cache_syncs, 1, "device span must sync the pair once");
    } else {
        // Not silent: the engine must have EXPLICITLY gone host-sticky
        // (wrapper cannot chain buffers); a device path that quietly
        // degrades without flipping the health bit is a regression.
        eprintln!("note: device path unavailable — upload-count asserts skipped");
    }

    eng.set_device_kv(false);
    let before = stats.snapshot();
    let mut host_caches = mk_caches();
    let host = eng
        .decode_span(StepPath::Precompute, &span, 0, &mut host_caches)
        .unwrap();
    let h = stats.snapshot().since(&before);
    assert_eq!(h.cache_uploads, span.len() as u64, "host path uploads per token");
    assert_eq!(h.cache_h2d_bytes, pair_bytes * span.len() as u64);
    eng.set_device_kv(true);

    assert_eq!(dev.logits, host.logits, "span logits diverge across paths");
    assert_eq!(dev.new_k, host.new_k, "span K rows diverge across paths");
    assert_eq!(dev.new_v, host.new_v, "span V rows diverge across paths");
    // The host mirror the caller sees must agree on the written span.
    let row = cfg.n_kv_heads * cfg.head_dim();
    for l in 0..cfg.n_layers {
        for p in 0..span.len() {
            let o = dev_caches.offset(l, 0, p);
            assert_eq!(
                dev_caches.k[o..o + row],
                host_caches.k[o..o + row],
                "cache mirror diverges at layer {l} pos {p}"
            );
        }
    }
}

/// Device-resident vs legacy host KV must be temperature-0
/// TOKEN-IDENTICAL end to end across the three serving shapes that
/// exercise every sync point: chunked prefill (span sessions), KV
/// pressure with preemption + requeue (session writeback and replay),
/// and a prefix-cache hit served as a suffix-only span fill.
#[test]
fn device_resident_kv_matches_host_path() {
    let dir = require_artifacts!();
    let mut all: Vec<Vec<Vec<u32>>> = Vec::new();
    for enable_device in [false, true] {
        let mut outputs: Vec<Vec<u32>> = Vec::new();

        // Scenario 1: chunked prefill + steady-state decode batches.
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_device_kv = enable_device;
            cfg.prefill_chunk_tokens = 8;
            cfg.step_token_budget = 16;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let prompts: Vec<Vec<u32>> = vec![
                vec![3; 24],
                (0..21).map(|i| (i * 7 % 500) as u32).collect(),
                vec![2],
            ];
            let ids: Vec<u64> = prompts
                .iter()
                .map(|p| {
                    c.submit(GenRequest {
                        prompt: p.clone(),
                        max_new_tokens: 10,
                        priority: Priority::Normal,
                        params: SamplingParams::default(),
                    })
                    .unwrap()
                })
                .collect();
            // Step manually so a live device session is observable, and
            // guard against the device path silently regressing to the
            // host fallback: either sessions formed, or the engine
            // explicitly reports itself host-sticky.
            let mut saw_session = false;
            let mut steps = 0;
            while c.busy() {
                c.step().unwrap();
                saw_session |= c.device_session_active();
                steps += 1;
                assert!(steps < 50_000, "did not drain");
            }
            if enable_device {
                use std::sync::atomic::Ordering::Relaxed;
                assert!(
                    (saw_session && c.metrics.kv_sessions.load(Relaxed) > 0)
                        || !c.engine().device_kv_active(),
                    "no device session formed, yet the engine claims the \
                     device path is healthy (silent host fallback)"
                );
            } else {
                assert!(!saw_session, "host-only run built a device session");
            }
            for id in &ids {
                outputs.push(c.generated(*id).unwrap().to_vec());
            }
        }

        // Scenario 2: tiny pool -> preemption mid-generation, requeue,
        // replay (session rows dropped for victims, synced for others).
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_device_kv = enable_device;
            cfg.kv_blocks = 8;
            cfg.kv_block_tokens = 16;
            cfg.max_batch = 4;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let ids: Vec<u64> = (0..4)
                .map(|i| {
                    c.submit(GenRequest {
                        prompt: vec![2 + i as u32 * 3; 20],
                        max_new_tokens: 24,
                        priority: Priority::Normal,
                        params: SamplingParams::default(),
                    })
                    .unwrap()
                })
                .collect();
            c.run_to_completion(20_000).unwrap();
            assert!(
                c.metrics
                    .preemptions
                    .load(std::sync::atomic::Ordering::Relaxed)
                    > 0,
                "scenario must exercise preemption (device={enable_device})"
            );
            for id in &ids {
                outputs.push(c.generated(*id).unwrap().to_vec());
            }
        }

        // Scenario 3: prefix-cache hit -> suffix-only span fill.
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_device_kv = enable_device;
            cfg.enable_prefix_cache = true;
            cfg.kv_block_tokens = 8;
            cfg.prefill_chunk_tokens = 8;
            cfg.step_token_budget = 16;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let system: Vec<u32> = (0..24).map(|i| (i * 13 % 500) as u32).collect();
            for suffix in [&[7u32, 9, 11][..], &[401, 3, 77, 12][..]] {
                let mut p = system.clone();
                p.extend_from_slice(suffix);
                let id = c
                    .submit(GenRequest {
                        prompt: p,
                        max_new_tokens: 8,
                        priority: Priority::Normal,
                        params: SamplingParams::default(),
                    })
                    .unwrap();
                c.run_to_completion(50_000).unwrap();
                outputs.push(c.generated(id).unwrap().to_vec());
            }
            assert!(
                c.metrics
                    .prefix_hits
                    .load(std::sync::atomic::Ordering::Relaxed)
                    >= 1,
                "scenario must exercise a prefix-cache hit (device={enable_device})"
            );
        }

        all.push(outputs);
    }
    assert_eq!(
        all[0], all[1],
        "device-resident KV diverges from the legacy host path at temperature 0"
    );
}

/// Admission control: once `max_waiting` requests queue up, further
/// submits bounce with `Error::Backpressure` — and the engine still
/// drains everything it accepted.
#[test]
fn backpressure_rejects_then_drains() {
    let dir = require_artifacts!();
    let mut cfg = serving(&dir, "tiny-serial", true);
    cfg.max_waiting = 2;
    let mut c = Coordinator::from_config(&cfg).unwrap();
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for i in 0..5u32 {
        let r = c.submit(GenRequest {
            prompt: vec![4 + i; 6],
            max_new_tokens: 4,
            priority: Priority::Normal,
            params: SamplingParams::default(),
        });
        match r {
            Ok(id) => accepted.push(id),
            Err(firstlayer::Error::Backpressure(_)) => rejected += 1,
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert_eq!(accepted.len(), 2);
    assert_eq!(rejected, 3);
    assert_eq!(
        c.metrics
            .requests_rejected
            .load(std::sync::atomic::Ordering::Relaxed),
        3
    );
    c.run_to_completion(10_000).unwrap();
    for id in accepted {
        assert!(c.finished(id).is_some());
    }
}

/// KV pressure: a tiny block pool forces preemption mid-generation; the
/// preempted request must still complete with the right token count.
#[test]
fn preemption_recovers_and_completes() {
    let dir = require_artifacts!();
    let mut cfg = serving(&dir, "tiny-serial", true);
    cfg.kv_blocks = 8; // 8 blocks * 16 tokens: room for ~2 sequences
    cfg.kv_block_tokens = 16;
    cfg.max_batch = 4;
    let mut c = Coordinator::from_config(&cfg).unwrap();
    let ids: Vec<u64> = (0..4)
        .map(|i| {
            c.submit(GenRequest {
                prompt: vec![2 + i as u32 * 3; 20],
                max_new_tokens: 24,
                priority: Priority::Normal,
                params: SamplingParams::default(),
            })
            .unwrap()
        })
        .collect();
    c.run_to_completion(20_000).unwrap();
    for id in ids {
        let got = c.generated(id).unwrap().len();
        assert!(
            got == 24 || c.finished(id).is_some(),
            "req {id}: incomplete ({got} tokens)"
        );
    }
    // The pool was small enough that at least one preemption should have
    // happened (not guaranteed by spec, but with these sizes it is).
    let preempts = c
        .metrics
        .preemptions
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(preempts > 0, "expected KV pressure to trigger preemption");
}

/// Priority classes: an interactive request admitted later still finishes
/// no later than batch-class requests submitted first (single-slot batch).
#[test]
fn interactive_priority_served_first() {
    let dir = require_artifacts!();
    let mut cfg = serving(&dir, "tiny-serial", true);
    cfg.max_batch = 1;
    cfg.max_admit_per_step = 1;
    let mut c = Coordinator::from_config(&cfg).unwrap();
    let slow = c
        .submit(GenRequest {
            prompt: vec![5; 4],
            max_new_tokens: 8,
            priority: Priority::Batch,
            params: SamplingParams::default(),
        })
        .unwrap();
    let fast = c
        .submit(GenRequest {
            prompt: vec![9; 4],
            max_new_tokens: 8,
            priority: Priority::Interactive,
            params: SamplingParams::default(),
        })
        .unwrap();
    // Step until the interactive one finishes; the batch one must not have
    // produced more tokens than it.
    let mut steps = 0;
    while c.finished(fast).is_none() && steps < 1000 {
        c.step().unwrap();
        steps += 1;
    }
    assert!(c.finished(fast).is_some());
    assert!(
        c.generated(slow).unwrap_or(&[]).len() <= c.generated(fast).unwrap().len(),
        "batch-class request overtook the interactive one"
    );
    c.run_to_completion(10_000).unwrap();
}

/// `build_table` (PJRT re-derivation) reproduces the shipped table.  The
/// two compiler stacks (jax CPU jit vs xla_extension 0.5.1) need not be
/// bit-identical, but must agree to f32 accumulation noise.
#[test]
fn table_rebuild_matches_shipped() {
    let dir = require_artifacts!();
    for model in ["tiny-serial", "tiny-parallel"] {
        let (_rt, eng) = engine(&dir, model);
        let rebuilt = eng.build_table().unwrap();
        let diff = firstlayer::precompute::max_abs_diff(&rebuilt, eng.table()).unwrap();
        assert!(
            diff < 1e-4,
            "{model}: rebuilt table differs from shipped (max {diff})"
        );
    }
}

/// Traffic accounting: measured counters equal the analytical model for the
/// executed step sequence (E3's core assertion).
#[test]
fn traffic_counters_match_costmodel() {
    let dir = require_artifacts!();
    let (_rt, eng) = engine(&dir, "tiny-serial");
    let cfg = eng.config().clone();
    eng.traffic.reset();
    let caches = CacheBatch::zeros(
        cfg.n_layers,
        eng.decode_bucket(2, StepPath::Baseline).unwrap(),
        cfg.max_seq,
        cfg.n_kv_heads,
        cfg.head_dim(),
    );
    for _ in 0..3 {
        eng.decode(StepPath::Baseline, &[1, 2], &[0, 0], &caches)
            .unwrap();
        eng.decode(StepPath::Precompute, &[1, 2], &[0, 0], &caches)
            .unwrap();
    }
    let t = eng.traffic.snapshot();
    use firstlayer::costmodel;
    assert_eq!(t.l1_reads_baseline, 3 * costmodel::reads_without(&cfg, 2));
    assert_eq!(t.l1_reads_precomp, 3 * costmodel::reads_with(&cfg, 2));
    assert_eq!(t.table_bytes_read, t.l1_reads_precomp * 4);
}

/// The abs-PE model must refuse the precompute path end to end.
#[test]
fn abspe_model_rejects_precompute() {
    let dir = require_artifacts!();
    // tiny-abspe has no artifacts (it exists for the negative config test),
    // so exercise the engine guard directly on a rope model by forging the
    // config check at the coordinator level instead.
    let cfg = serving(&dir, "tiny-serial", true);
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let eng = Arc::new(ModelEngine::load(&rt, &manifest, &cfg.model).unwrap());
    // Engine-level: precompute on a non-rope config errors (simulated by
    // checking the error text path exists for PrecomputeGather with rope ok).
    assert!(eng.config().rope);
    // Coordinator-level: constructing with a fake non-rope name fails early.
    let mut bad = cfg.clone();
    bad.model = "tiny-abspe".to_string();
    assert!(Coordinator::from_config(&bad).is_err());
}

/// Server round-trip over a real TCP socket.
#[test]
fn server_tcp_roundtrip() {
    let dir = require_artifacts!();
    use std::io::{BufRead, BufReader, Write};
    let cfg = serving(&dir, "tiny-serial", true);
    let addr = "127.0.0.1:7911";
    std::thread::spawn(move || {
        let server = firstlayer::server::Server::new(addr);
        let _ = server.run(move || Coordinator::from_config(&cfg));
    });
    // Wait for the port to open.
    let mut stream = None;
    for _ in 0..100 {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(100)),
        }
    }
    let mut stream = stream.expect("server did not come up");
    stream
        .write_all(b"{\"op\":\"ping\"}\n{\"op\":\"generate\",\"prompt\":\"the quick\",\"max_new_tokens\":4}\n")
        .unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    let mut tokens = 0;
    let mut done = false;
    let mut pong = false;
    for line in reader.lines() {
        let line = line.unwrap();
        let v = firstlayer::util::json::parse(&line).unwrap();
        match v.get_opt("event").and_then(|e| e.as_str()) {
            Some("pong") => pong = true,
            Some("token") => tokens += 1,
            Some("done") => {
                done = true;
                break;
            }
            other => panic!("unexpected event {other:?} in {line}"),
        }
    }
    assert!(pong, "no pong");
    assert!(done, "no done event");
    assert_eq!(tokens, 4);
    // Metrics query on a fresh connection.
    let mut m = std::net::TcpStream::connect(addr).unwrap();
    m.write_all(b"{\"op\":\"traffic\"}\n").unwrap();
    let mut line = String::new();
    BufReader::new(m).read_line(&mut line).unwrap();
    let v = firstlayer::util::json::parse(&line).unwrap();
    assert!(v.get_opt("l1_reads_precomp").is_some());
}
