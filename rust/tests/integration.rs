//! Integration tests over the real AOT artifacts (E4/E5/E6 rust side).
//!
//! These need `make artifacts` to have run; they are skipped (cleanly)
//! when the bundle is missing so `cargo test` works on a fresh checkout.

use std::sync::Arc;

use firstlayer::config::ServingConfig;
use firstlayer::coordinator::sampling::SamplingParams;
use firstlayer::coordinator::{Coordinator, FinishReason, Request};
use firstlayer::manifest::Manifest;
use firstlayer::runtime::{CacheBatch, ModelEngine, Runtime, SpanLane, StepPath};
use firstlayer::scheduler::Priority;
use firstlayer::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

fn engine(dir: &std::path::Path, model: &str) -> (Runtime, ModelEngine) {
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(dir).unwrap();
    let e = ModelEngine::load(&rt, &manifest, model).unwrap();
    (rt, e)
}

fn serving(dir: &std::path::Path, model: &str, precompute: bool) -> ServingConfig {
    ServingConfig {
        artifacts_dir: dir.to_string_lossy().into_owned(),
        model: model.to_string(),
        use_precompute: precompute,
        ..Default::default()
    }
}

/// E4/E5: engine-level equivalence — logits argmax and the written KV rows
/// agree between the two paths across random batches and positions.
#[test]
fn decode_paths_equivalent_all_models() {
    let dir = require_artifacts!();
    for model in ["tiny-serial", "tiny-parallel", "tiny-moe", "tiny-moe-parallel"] {
        let (_rt, eng) = engine(&dir, model);
        let cfg = eng.config().clone();
        let mut rng = Rng::new(42);
        for n in [1usize, 2] {
            let bucket = eng.decode_bucket(n, StepPath::Baseline).unwrap();
            let mut caches = CacheBatch::zeros(
                cfg.n_layers,
                bucket,
                cfg.max_seq,
                cfg.n_kv_heads,
                cfg.head_dim(),
            );
            // Random (but shared) cache contents + positions.
            for x in caches.k.iter_mut().chain(caches.v.iter_mut()) {
                *x = (rng.f64() as f32) - 0.5;
            }
            let tokens: Vec<u32> = (0..n)
                .map(|_| rng.below(cfg.vocab_size as u64) as u32)
                .collect();
            let pos: Vec<u32> = (0..n).map(|_| rng.below(20) as u32 + 1).collect();
            let base = eng
                .decode(StepPath::Baseline, &tokens, &pos, &caches)
                .unwrap();
            let pre = eng
                .decode(StepPath::Precompute, &tokens, &pos, &caches)
                .unwrap();
            let v = cfg.vocab_size;
            for i in 0..n {
                let lb = &base.logits[i * v..(i + 1) * v];
                let lp = &pre.logits[i * v..(i + 1) * v];
                let max_diff = lb
                    .iter()
                    .zip(lp)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                assert!(max_diff < 1e-3, "{model} n={n} seq {i}: diff {max_diff}");
            }
            let kdiff = base
                .new_k
                .iter()
                .zip(&pre.new_k)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(kdiff < 1e-3, "{model}: new K rows diverge ({kdiff})");
        }
    }
}

/// The ablation artifact (in-graph Pallas gather) agrees too.
#[test]
fn gather_ablation_equivalent() {
    let dir = require_artifacts!();
    let (_rt, eng) = engine(&dir, "tiny-serial");
    let cfg = eng.config().clone();
    let n = 3;
    let bucket = eng.decode_bucket(n, StepPath::PrecomputeGather).unwrap();
    let caches = CacheBatch::zeros(
        cfg.n_layers,
        bucket,
        cfg.max_seq,
        cfg.n_kv_heads,
        cfg.head_dim(),
    );
    let tokens = [7u32, 400, 3];
    let pos = [0u32, 0, 0];
    let a = eng
        .decode(StepPath::Precompute, &tokens, &pos, &caches)
        .unwrap();
    let b = eng
        .decode(StepPath::PrecomputeGather, &tokens, &pos, &caches)
        .unwrap();
    let diff = a
        .logits
        .iter()
        .zip(&b.logits)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(diff < 1e-4, "gather ablation diverges: {diff}");
}

/// E6: full coordinator runs produce identical greedy outputs on both paths.
#[test]
fn coordinator_greedy_outputs_identical() {
    let dir = require_artifacts!();
    let prompts = [
        "the quick brown fox",
        "attention is",
        "memory bandwidth limits",
        "a",
    ];
    let mut outputs: Vec<Vec<Vec<u32>>> = Vec::new();
    for precompute in [false, true] {
        let cfg = serving(&dir, "tiny-serial", precompute);
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let ids: Vec<u64> = prompts
            .iter()
            .map(|p| c.submit(Request::from_text(*p, 12)).unwrap())
            .collect();
        c.run_to_completion(10_000).unwrap();
        outputs.push(
            ids.iter()
                .map(|id| c.generated(*id).unwrap().to_vec())
                .collect(),
        );
    }
    assert_eq!(
        outputs[0], outputs[1],
        "baseline vs precompute greedy outputs diverge"
    );
}

/// Decode after prefill must be position-consistent: generating one token
/// at a time from a 1-token prompt equals the coordinator's own output.
#[test]
fn coordinator_deterministic_across_runs() {
    let dir = require_artifacts!();
    let cfg = serving(&dir, "tiny-parallel", true);
    let mut outs = Vec::new();
    for _ in 0..2 {
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let id = c
            .submit(Request::from_text("the scheduler admits", 10))
            .unwrap();
        c.run_to_completion(10_000).unwrap();
        outs.push(c.generated(id).unwrap().to_vec());
    }
    assert_eq!(outs[0], outs[1]);
}

/// Chunked prefill must be token-identical to monolithic prefill at
/// temperature 0: splitting a prompt into table-gather + decode-kernel
/// spans changes the compute schedule, never the math.
#[test]
fn chunked_prefill_matches_monolithic() {
    let dir = require_artifacts!();
    let prompts: Vec<Vec<u32>> = vec![
        vec![3; 24],
        vec![11; 17],
        (0..21).map(|i| (i * 7 % 500) as u32).collect(),
        vec![2], // single-token prompt: first chunk is also the last
    ];
    let mut outs: Vec<Vec<Vec<u32>>> = Vec::new();
    for chunk in [0usize, 8] {
        let mut cfg = serving(&dir, "tiny-serial", true);
        cfg.prefill_chunk_tokens = chunk;
        cfg.step_token_budget = if chunk == 0 { 0 } else { 16 };
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let ids: Vec<u64> = prompts
            .iter()
            .map(|p| c.submit(Request::from_tokens(p.clone(), 10)).unwrap())
            .collect();
        c.run_to_completion(50_000).unwrap();
        if chunk > 0 {
            // The 24/17/21-token prompts cannot fit one 8-token chunk.
            let chunks = c
                .metrics
                .prefill_chunks
                .load(std::sync::atomic::Ordering::Relaxed);
            assert!(chunks > 4, "expected chunked execution, got {chunks}");
        }
        outs.push(
            ids.iter()
                .map(|id| c.generated(*id).unwrap().to_vec())
                .collect(),
        );
    }
    assert_eq!(
        outs[0], outs[1],
        "chunked prefill diverges from monolithic at temperature 0"
    );
}

/// Cross-request prefix cache: two requests sharing a long system prompt
/// produce token-identical output at temperature 0 with the cache on vs
/// off, and the second request executes strictly fewer prefill tokens
/// (the cached span is forked, not recomputed — neither attention nor
/// the first-layer table gather run for it).
#[test]
fn prefix_cache_reuses_shared_system_prompt() {
    let dir = require_artifacts!();
    // 24-token shared "system prompt" (3 full 8-token KV blocks are
    // cacheable) + distinct short user suffixes; prompts stay under the
    // tiny models' 32-token prefill bucket.
    let system: Vec<u32> = (0..24).map(|i| (i * 13 % 500) as u32).collect();
    let mk = |suffix: &[u32]| {
        let mut p = system.clone();
        p.extend_from_slice(suffix);
        p
    };
    let prompts = [mk(&[7, 9, 11]), mk(&[401, 3, 77, 12])];
    let mut outs: Vec<Vec<Vec<u32>>> = Vec::new();
    let mut prefill_tokens_per_req: Vec<Vec<u64>> = Vec::new();
    for enable in [false, true] {
        let mut cfg = serving(&dir, "tiny-serial", true);
        cfg.enable_prefix_cache = enable;
        cfg.kv_block_tokens = 8;
        cfg.prefill_chunk_tokens = 8;
        cfg.step_token_budget = 16;
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let mut per_req = Vec::new();
        let mut ids = Vec::new();
        // Sequentially: the first request must be finished (and inserted
        // into the cache) before the second submits and matches.
        for p in &prompts {
            let before = c.engine().traffic.snapshot().prefill_tokens;
            let id = c.submit(Request::from_tokens(p.clone(), 8)).unwrap();
            c.run_to_completion(50_000).unwrap();
            per_req.push(c.engine().traffic.snapshot().prefill_tokens - before);
            ids.push(id);
        }
        if enable {
            use std::sync::atomic::Ordering::Relaxed;
            assert!(c.metrics.prefix_hits.load(Relaxed) >= 1, "no cache hit");
            assert_eq!(
                c.metrics.prefix_cached_tokens.load(Relaxed),
                24,
                "second request should reuse the system prompt's 3 blocks"
            );
            assert!(c.prefix_cache_blocks_held() > 0);
        }
        outs.push(
            ids.iter()
                .map(|id| c.generated(*id).unwrap().to_vec())
                .collect(),
        );
        prefill_tokens_per_req.push(per_req);
    }
    assert_eq!(
        outs[0], outs[1],
        "prefix cache changed temperature-0 output"
    );
    // Cache off: both requests prefill their whole prompt.  Cache on:
    // the first (cold) does too, the second prefills only its suffix.
    assert_eq!(prefill_tokens_per_req[0][1], prompts[1].len() as u64);
    assert_eq!(prefill_tokens_per_req[1][0], prompts[0].len() as u64);
    assert!(
        prefill_tokens_per_req[1][1] < prefill_tokens_per_req[0][1],
        "cache hit did not reduce executed prefill tokens \
         ({} vs {})",
        prefill_tokens_per_req[1][1],
        prefill_tokens_per_req[0][1]
    );
    assert_eq!(
        prefill_tokens_per_req[1][1],
        (prompts[1].len() - 24) as u64,
        "second request should prefill exactly the uncached suffix"
    );
}

/// Device-resident KV: a span chained through one `DeviceCacheSession`
/// uploads the cache pair exactly ONCE (the acceptance criterion the
/// transfer counters make measurable), where the host path uploads it
/// once per token — and the two paths produce bit-identical logits and
/// K/V rows (same kernels, same inputs; chaining only changes where the
/// bytes live between steps).  Batched span execution is disabled here:
/// this test pins the token-by-token oracle's transfer schedule, which
/// the span-artifact tests below compare against.
#[test]
fn device_span_uploads_cache_once_and_matches_host() {
    let dir = require_artifacts!();
    let (_rt, eng) = engine(&dir, "tiny-serial");
    eng.set_span_exec(false);
    let cfg = eng.config().clone();
    let bucket = eng.decode_bucket(1, StepPath::Precompute).unwrap();
    let mk_caches = || {
        CacheBatch::zeros(
            cfg.n_layers,
            bucket,
            cfg.max_seq,
            cfg.n_kv_heads,
            cfg.head_dim(),
        )
    };
    let span: Vec<u32> = (0..6u32).map(|i| (i * 31) % cfg.vocab_size as u32).collect();
    let pair_bytes =
        2 * (cfg.n_layers * bucket * cfg.max_seq * cfg.n_kv_heads * cfg.head_dim()) as u64 * 4;

    eng.set_device_kv(true);
    let stats = eng.transfers();
    let before = stats.snapshot();
    let mut dev_caches = mk_caches();
    let dev = eng
        .decode_span(StepPath::Precompute, &span, 0, &mut dev_caches)
        .unwrap();
    let d = stats.snapshot().since(&before);
    if eng.device_kv_active() {
        assert_eq!(d.cache_uploads, 1, "device span must upload the pair once");
        assert_eq!(d.cache_h2d_bytes, pair_bytes);
        assert_eq!(d.cache_syncs, 1, "device span must sync the pair once");
    } else {
        // Not silent: the engine must have EXPLICITLY gone host-sticky
        // (wrapper cannot chain buffers); a device path that quietly
        // degrades without flipping the health bit is a regression.
        eprintln!("note: device path unavailable — upload-count asserts skipped");
    }

    eng.set_device_kv(false);
    let before = stats.snapshot();
    let mut host_caches = mk_caches();
    let host = eng
        .decode_span(StepPath::Precompute, &span, 0, &mut host_caches)
        .unwrap();
    let h = stats.snapshot().since(&before);
    assert_eq!(h.cache_uploads, span.len() as u64, "host path uploads per token");
    assert_eq!(h.cache_h2d_bytes, pair_bytes * span.len() as u64);
    eng.set_device_kv(true);

    assert_eq!(dev.logits, host.logits, "span logits diverge across paths");
    assert_eq!(dev.new_k, host.new_k, "span K rows diverge across paths");
    assert_eq!(dev.new_v, host.new_v, "span V rows diverge across paths");
    // The host mirror the caller sees must agree on the written span.
    let row = cfg.n_kv_heads * cfg.head_dim();
    for l in 0..cfg.n_layers {
        for p in 0..span.len() {
            let o = dev_caches.offset(l, 0, p);
            assert_eq!(
                dev_caches.k[o..o + row],
                host_caches.k[o..o + row],
                "cache mirror diverges at layer {l} pos {p}"
            );
        }
    }
}

/// Batched span execution (engine level): a span served through the
/// compiled span artifact must match the token-by-token oracle — logits
/// at the span end, the fresh K/V rows, and the advanced cache mirror —
/// on BOTH serving paths, while costing at most `ceil(len/T)` device
/// executions (the acceptance criterion, asserted via the engine's
/// execution counters).  Ragged spans (len % T != 0) included.
#[test]
fn batched_span_matches_token_by_token_and_bounds_executions() {
    let dir = require_artifacts!();
    let (_rt, eng) = engine(&dir, "tiny-serial");
    let cfg = eng.config().clone();
    let buckets = eng.span_buckets_for(StepPath::Precompute);
    if buckets.is_empty() {
        eprintln!("skipping: bundle has no span artifacts (re-run `make artifacts`)");
        return;
    }
    let largest = *buckets.last().unwrap();
    for path in [StepPath::Baseline, StepPath::Precompute] {
        let bucket = eng.decode_bucket(1, path).unwrap();
        let mk = || {
            CacheBatch::zeros(
                cfg.n_layers,
                bucket,
                cfg.max_seq,
                cfg.n_kv_heads,
                cfg.head_dim(),
            )
        };
        // A short real history first (built by the oracle on BOTH copies)
        // so the span attends actual KV, not zeros.
        let hist: Vec<u32> = (0..5u32).map(|i| (i * 13 + 3) % cfg.vocab_size as u32).collect();
        for span_len in [64usize.min(cfg.max_seq - 1 - hist.len()), 13] {
            let tokens: Vec<u32> = (0..span_len)
                .map(|i| (i as u32 * 31 + 7) % cfg.vocab_size as u32)
                .collect();
            let mut bc = mk();
            let mut oc = mk();
            eng.set_span_exec(false);
            eng.decode_span(path, &hist, 0, &mut bc).unwrap();
            eng.decode_span(path, &hist, 0, &mut oc).unwrap();

            eng.set_span_exec(true);
            let execs_before = eng.span_executions();
            let b = eng.decode_span(path, &tokens, hist.len(), &mut bc).unwrap();
            assert!(
                b.batched || !eng.span_exec_active(),
                "span artifacts present but the batched path silently \
                 declined while claiming health"
            );
            if !b.batched {
                eprintln!("note: batched span path unavailable — bound asserts skipped");
                return;
            }
            let execs = eng.span_executions() - execs_before;
            assert_eq!(execs as usize, b.executions);
            assert!(
                b.executions <= span_len.div_ceil(largest),
                "{} len={span_len}: {} executions > ceil({span_len}/{largest})",
                path.label(),
                b.executions
            );
            assert_eq!(b.exec_tokens.iter().sum::<usize>(), span_len);

            eng.set_span_exec(false);
            let o = eng.decode_span(path, &tokens, hist.len(), &mut oc).unwrap();
            eng.set_span_exec(true);
            assert!(!o.batched);
            assert_eq!(o.executions, span_len, "oracle is one dispatch per token");

            let vdiff = b
                .logits
                .iter()
                .zip(&o.logits)
                .map(|(a, c)| (a - c).abs())
                .fold(0f32, f32::max);
            assert!(
                vdiff < 1e-3,
                "{} len={span_len}: span-end logits diverge ({vdiff})",
                path.label()
            );
            assert_eq!(
                firstlayer::coordinator::sampling::argmax(&b.logits),
                firstlayer::coordinator::sampling::argmax(&o.logits),
                "{} len={span_len}: greedy token diverges",
                path.label()
            );
            let kdiff = b
                .new_k
                .iter()
                .zip(&o.new_k)
                .chain(b.new_v.iter().zip(&o.new_v))
                .map(|(a, c)| (a - c).abs())
                .fold(0f32, f32::max);
            assert!(kdiff < 1e-3, "{}: span K/V rows diverge ({kdiff})", path.label());
            // The caller-visible cache mirror agrees over the span rows.
            let row = cfg.n_kv_heads * cfg.head_dim();
            for l in 0..cfg.n_layers {
                for p in 0..span_len {
                    let off = bc.offset(l, 0, hist.len() + p);
                    let d = bc.k[off..off + row]
                        .iter()
                        .zip(&oc.k[off..off + row])
                        .map(|(a, c)| (a - c).abs())
                        .fold(0f32, f32::max);
                    assert!(d < 1e-3, "mirror diverges at layer {l} pos {p}");
                }
            }
        }
    }
    // With device chaining available, a batched span still uploads the
    // pair exactly once (session begin) and — unlike the token-by-token
    // device path — needs NO span-end pair sync: fresh rows come back as
    // artifact outputs.
    if eng.device_kv_active() && eng.span_exec_active() {
        let bucket = eng.decode_bucket(1, StepPath::Precompute).unwrap();
        let mut caches = CacheBatch::zeros(
            cfg.n_layers,
            bucket,
            cfg.max_seq,
            cfg.n_kv_heads,
            cfg.head_dim(),
        );
        let tokens: Vec<u32> = (0..24u32).collect();
        let stats = eng.transfers();
        let before = stats.snapshot();
        let out = eng
            .decode_span(StepPath::Precompute, &tokens, 0, &mut caches)
            .unwrap();
        let d = stats.snapshot().since(&before);
        if out.batched {
            assert_eq!(d.cache_uploads, 1, "batched span must upload the pair once");
            assert_eq!(d.cache_syncs, 0, "fresh-row outputs replace the pair sync");
        }
    }
}

/// Batched span execution (coordinator level): temperature-0 token
/// streams must be identical with the span artifact on vs the per-token
/// oracle across every serving shape that runs spans — chunked prefill
/// continuations, prefix-cache suffix fills, and preemption + replay —
/// ragged tails included (chunk sizes indivisible by the span buckets).
#[test]
fn batched_span_serving_matches_oracle_across_shapes() {
    let dir = require_artifacts!();
    let mut all: Vec<Vec<Vec<u32>>> = Vec::new();
    let mut batched_spans_seen = false;
    for enable_span in [false, true] {
        let mut outputs: Vec<Vec<u32>> = Vec::new();

        // Scenario 1: chunked prefill with a ragged chunk size (7 % 8
        // != 0) and long prompts -> continuation spans with ragged tails.
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_span_exec = enable_span;
            cfg.prefill_chunk_tokens = 7;
            cfg.step_token_budget = 16;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let prompts: Vec<Vec<u32>> = vec![
                vec![3; 24],
                (0..37).map(|i| (i * 7 % 500) as u32).collect(),
                vec![2],
            ];
            let ids: Vec<u64> = prompts
                .iter()
                .map(|p| c.submit(Request::from_tokens(p.clone(), 10)).unwrap())
                .collect();
            c.run_to_completion(50_000).unwrap();
            use std::sync::atomic::Ordering::Relaxed;
            if enable_span && c.engine().span_exec_active() {
                assert!(
                    c.metrics.span_executions.load(Relaxed) > 0,
                    "span-enabled run executed no span artifacts"
                );
                assert_eq!(
                    c.metrics.span_fallbacks.load(Relaxed),
                    0,
                    "healthy span path must not fall back"
                );
                batched_spans_seen = true;
            }
            for id in &ids {
                outputs.push(c.generated(*id).unwrap().to_vec());
            }
        }

        // Scenario 2: prefix-cache hit -> suffix-only span fill.
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_span_exec = enable_span;
            cfg.enable_prefix_cache = true;
            cfg.kv_block_tokens = 8;
            cfg.prefill_chunk_tokens = 8;
            cfg.step_token_budget = 16;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let system: Vec<u32> = (0..24).map(|i| (i * 13 % 500) as u32).collect();
            for suffix in [&[7u32, 9, 11][..], &[401, 3, 77, 12][..]] {
                let mut p = system.clone();
                p.extend_from_slice(suffix);
                let id = c.submit(Request::from_tokens(p, 8)).unwrap();
                c.run_to_completion(50_000).unwrap();
                outputs.push(c.generated(id).unwrap().to_vec());
            }
            assert!(
                c.metrics
                    .prefix_hits
                    .load(std::sync::atomic::Ordering::Relaxed)
                    >= 1,
                "scenario must exercise a prefix-cache hit"
            );
        }

        // Scenario 3: tiny pool -> preemption mid-generation + replay
        // (over-bucket replays execute head-via-artifact + excess spans).
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_span_exec = enable_span;
            cfg.kv_blocks = 8;
            cfg.kv_block_tokens = 16;
            cfg.max_batch = 4;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let ids: Vec<u64> = (0..4)
                .map(|i| {
                    c.submit(Request::from_tokens(vec![2 + i as u32 * 3; 20], 24))
                        .unwrap()
                })
                .collect();
            c.run_to_completion(20_000).unwrap();
            assert!(
                c.metrics
                    .preemptions
                    .load(std::sync::atomic::Ordering::Relaxed)
                    > 0,
                "scenario must exercise preemption (span={enable_span})"
            );
            for id in &ids {
                outputs.push(c.generated(*id).unwrap().to_vec());
            }
        }

        all.push(outputs);
    }
    assert_eq!(
        all[0], all[1],
        "batched span execution diverges from the per-token oracle at \
         temperature 0"
    );
    assert!(
        batched_spans_seen,
        "no scenario actually exercised the batched span path"
    );
}

/// Multi-sequence span group (engine level): a `[B, T]` group over ragged
/// lanes must match each lane's token-by-token oracle (logits, fresh K/V
/// rows) while uploading the cache pair exactly ONCE for the whole group
/// (session begin covers every lane) and syncing it back ZERO times —
/// fresh rows come back as artifact outputs.  Extends
/// `device_span_uploads_cache_once_and_matches_host` to the grouped path.
#[test]
fn span_group_uploads_cache_once_and_matches_per_lane_oracle() {
    let dir = require_artifacts!();
    let (_rt, eng) = engine(&dir, "tiny-serial");
    let cfg = eng.config().clone();
    let path = StepPath::Precompute;
    let Some((batch, _ts)) = eng.span_batch_for(path, 2) else {
        eprintln!("skipping: bundle has no span-batch artifacts");
        return;
    };
    let s = cfg.max_seq;
    let lens = [13usize, 6];
    let toks: Vec<Vec<u32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            (0..n)
                .map(|j| ((i * 97 + j * 31 + 7) % cfg.vocab_size) as u32)
                .collect()
        })
        .collect();
    let lanes: Vec<SpanLane> = toks
        .iter()
        .map(|t| SpanLane { tokens: t, start: 0 })
        .collect();
    let mut caches =
        CacheBatch::zeros(cfg.n_layers, 2, s, cfg.n_kv_heads, cfg.head_dim());
    let stats = eng.transfers();
    let before = stats.snapshot();
    let out = eng.decode_span_group(path, &lanes, &mut caches).unwrap();
    let d = stats.snapshot().since(&before);
    assert_eq!(out.batch, batch);
    assert_eq!(out.lanes.len(), 2);
    assert_eq!(out.occupancy[0], 2, "first tile must run both lanes live");
    if eng.device_kv_active() {
        // ONE pair upload for the whole group — the widened [L, B, S, ·]
        // batch carries every lane — and no span-end pair sync.
        assert_eq!(d.cache_uploads, 1, "group must upload the pair once");
        assert_eq!(d.cache_syncs, 0, "fresh-row outputs replace the pair sync");
        let pair_bytes = 2
            * (cfg.n_layers * batch * s * cfg.n_kv_heads * cfg.head_dim()) as u64
            * 4;
        assert_eq!(d.cache_h2d_bytes, pair_bytes);
    }
    // Per-lane equivalence against the token-by-token oracle.
    let bucket = eng.decode_bucket(1, path).unwrap();
    let row = cfg.n_kv_heads * cfg.head_dim();
    for (i, t) in toks.iter().enumerate() {
        let mut oc =
            CacheBatch::zeros(cfg.n_layers, bucket, s, cfg.n_kv_heads, cfg.head_dim());
        eng.set_span_exec(false);
        let o = eng.decode_span(path, t, 0, &mut oc).unwrap();
        eng.set_span_exec(true);
        let ldiff = out.lanes[i]
            .logits
            .iter()
            .zip(&o.logits)
            .map(|(a, c)| (a - c).abs())
            .fold(0f32, f32::max);
        assert!(ldiff < 1e-3, "lane {i}: span-end logits diverge ({ldiff})");
        assert_eq!(
            firstlayer::coordinator::sampling::argmax(&out.lanes[i].logits),
            firstlayer::coordinator::sampling::argmax(&o.logits),
            "lane {i}: greedy token diverges"
        );
        let kdiff = out.lanes[i]
            .new_k
            .iter()
            .zip(&o.new_k)
            .chain(out.lanes[i].new_v.iter().zip(&o.new_v))
            .map(|(a, c)| (a - c).abs())
            .fold(0f32, f32::max);
        assert!(kdiff < 1e-3, "lane {i}: fresh K/V rows diverge ({kdiff})");
        // The caller's mirror holds the advanced lane — and NOTHING past
        // it: inert/padding-tile garbage must never leave the device.
        for l in 0..cfg.n_layers {
            for p in t.len()..(t.len() + 4).min(s) {
                let o = caches.offset(l, i, p);
                assert!(
                    caches.k[o..o + row].iter().all(|x| *x == 0.0),
                    "lane {i}: garbage leaked past the frontier (layer {l} pos {p})"
                );
            }
        }
    }
}

/// Acceptance: N same-bucket continuation chunks advance in ONE span
/// execution per group tile (engine counters), not N — and the grouped
/// run's temperature-0 streams equal the per-sequence oracle's.
#[test]
fn span_group_advances_same_bucket_continuations_in_one_execution() {
    let dir = require_artifacts!();
    let prompts: Vec<Vec<u32>> = (0..3u32)
        .map(|i| (0..24).map(|j| (i * 131 + j * 7 + 2) % 500).collect())
        .collect();
    let run = |batch: bool| {
        let mut cfg = serving(&dir, "tiny-serial", true);
        cfg.prefill_chunk_tokens = 8;
        cfg.step_token_budget = 64;
        cfg.enable_span_batch = batch;
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let ids: Vec<u64> = prompts
            .iter()
            .map(|p| c.submit(Request::from_tokens(p.clone(), 6)).unwrap())
            .collect();
        c.step().unwrap(); // fresh chunks via the batched prefill artifact
        let execs0 = c.engine().span_executions();
        let batched0 = c.engine().span_batched_executions();
        c.step().unwrap(); // 3 same-bucket continuation chunks (8 tokens)
        let execs = c.engine().span_executions() - execs0;
        let batched = c.engine().span_batched_executions() - batched0;
        c.run_to_completion(50_000).unwrap();
        let outs: Vec<Vec<u32>> =
            ids.iter().map(|id| c.generated(*id).unwrap().to_vec()).collect();
        (execs, batched, outs, c)
    };
    let (execs_on, batched_on, outs_on, c_on) = run(true);
    let (execs_off, batched_off, outs_off, _c_off) = run(false);
    assert_eq!(batched_off, 0, "span_batch off must never group");
    assert_eq!(
        outs_on, outs_off,
        "grouped spans diverge from the per-sequence oracle at temperature 0"
    );
    if c_on.engine().max_span_batch(StepPath::Precompute) < 3
        || !c_on.engine().span_batch_active()
    {
        eprintln!("note: span-batch capability missing — count asserts skipped");
        return;
    }
    assert_eq!(
        execs_off, 3,
        "oracle step must cost one span execution per sequence"
    );
    assert_eq!(
        execs_on, 1,
        "three same-bucket continuations must cost ONE span execution"
    );
    assert_eq!(batched_on, 1);
    use std::sync::atomic::Ordering::Relaxed;
    assert!(
        c_on.metrics.span_batched_executions.load(Relaxed) >= 1,
        "coordinator metric must surface the grouped executions"
    );
    assert!(
        c_on.metrics.report().contains("span_batch:"),
        "metrics report must carry the span_batch line"
    );
}

/// Property test: random mixed workloads — ragged span lengths,
/// interleaved admissions, a mid-flight cancel, and preemption + replay —
/// produce IDENTICAL temperature-0 token streams with multi-sequence
/// `[B, T]` span grouping on vs off (the per-sequence span path is the
/// oracle).  Grouping is a pure batching overlay: plans, schedules and
/// outputs must not change, only the execution count.
#[test]
fn span_group_serving_matches_oracle_mixed_workloads() {
    let dir = require_artifacts!();
    let mut rng = Rng::new(0xB17);
    // Shared deterministic workload: ragged prompt lengths around the
    // chunk/bucket sizes so groups mix tail lengths.
    let prompts: Vec<Vec<u32>> = (0..6)
        .map(|_| {
            let n = 15 + (rng.f64() * 25.0) as usize;
            (0..n).map(|_| (rng.f64() * 499.0) as u32).collect()
        })
        .collect();
    let mut all: Vec<Vec<Vec<u32>>> = Vec::new();
    let mut grouped_seen = false;
    for enable_batch in [false, true] {
        let mut outputs: Vec<Vec<u32>> = Vec::new();

        // Scenario 1: interleaved admissions + a mid-flight cancel over
        // ragged chunked prefills.  Grouping does not change the plan,
        // so the cancel lands at the identical point in both runs.
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_span_batch = enable_batch;
            cfg.prefill_chunk_tokens = 7;
            cfg.step_token_budget = 32;
            cfg.kv_block_tokens = 8;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let first: Vec<u64> = prompts[..3]
                .iter()
                .map(|p| c.submit(Request::from_tokens(p.clone(), 8)).unwrap())
                .collect();
            c.step().unwrap();
            c.step().unwrap();
            let late: Vec<u64> = prompts[3..]
                .iter()
                .map(|p| c.submit(Request::from_tokens(p.clone(), 8)).unwrap())
                .collect();
            c.step().unwrap();
            c.cancel(first[1]).unwrap();
            c.run_to_completion(50_000).unwrap();
            for id in first.iter().chain(&late) {
                outputs.push(c.generated(*id).unwrap().to_vec());
            }
            use std::sync::atomic::Ordering::Relaxed;
            if enable_batch && c.engine().span_batch_active() {
                grouped_seen |=
                    c.metrics.span_batched_executions.load(Relaxed) > 0;
            }
        }

        // Scenario 2: tiny pool -> preemption mid-generation + replay,
        // with ragged lengths (over-bucket replays span-continue).
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_span_batch = enable_batch;
            cfg.prefill_chunk_tokens = 8;
            cfg.step_token_budget = 32;
            cfg.kv_blocks = 8;
            cfg.kv_block_tokens = 16;
            cfg.max_batch = 4;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let ids: Vec<u64> = prompts[..4]
                .iter()
                .map(|p| c.submit(Request::from_tokens(p.clone(), 20)).unwrap())
                .collect();
            c.run_to_completion(50_000).unwrap();
            assert!(
                c.metrics
                    .preemptions
                    .load(std::sync::atomic::Ordering::Relaxed)
                    > 0,
                "scenario must exercise preemption (batch={enable_batch})"
            );
            for id in &ids {
                outputs.push(c.generated(*id).unwrap().to_vec());
            }
        }

        all.push(outputs);
    }
    assert_eq!(
        all[0], all[1],
        "grouped span serving diverges from the per-sequence oracle at \
         temperature 0"
    );
    // When the bundle compiles span batches, the mixed workload must have
    // actually exercised grouping (otherwise the equality is vacuous).
    let (_rt, eng) = engine(&dir, "tiny-serial");
    if eng.max_span_batch(StepPath::Precompute) >= 2 {
        assert!(
            grouped_seen,
            "span-batch capable bundle but no group was executed"
        );
    }
}

/// Speculative fan-out (`simtraffic::speculative_workload`): N variants
/// of each prompt race, the first natural finish wins its group, the
/// losers are cancelled mid-flight — span-heavy by construction (shared
/// prompts admit as prefix-cache suffix fills under chunked prefill).
/// Every loser must terminate `cancelled`, the pool invariants must
/// hold, and the winners' streams must be untouched.
#[test]
fn speculative_fanout_first_done_wins() {
    let dir = require_artifacts!();
    use std::collections::HashMap;
    let mut cfg = serving(&dir, "tiny-serial", true);
    cfg.prefill_chunk_tokens = 8;
    cfg.step_token_budget = 24;
    cfg.kv_block_tokens = 8;
    let mut c = Coordinator::from_config(&cfg).unwrap();
    let (n_groups, fanout) = (2usize, 3usize);
    let reqs =
        firstlayer::simtraffic::speculative_workload(n_groups, fanout, 20, 6, 500, 7);
    assert_eq!(reqs.len(), n_groups * fanout);
    let mut groups: HashMap<String, Vec<u64>> = HashMap::new();
    for mut r in reqs {
        let tag = r.tag.clone().unwrap();
        let (g, v) = tag.split_once('.').unwrap();
        // Stagger budgets by variant so each group has exactly one
        // earliest finisher (at temperature 0 equal budgets would all
        // finish the same step and leave nothing to cancel).
        r.max_new_tokens = 6 + v.parse::<usize>().unwrap() * 30;
        let id = c.submit(r).unwrap();
        groups.entry(g.to_string()).or_default().push(id);
    }
    let mut winners: HashMap<String, u64> = HashMap::new();
    let mut cancelled: Vec<u64> = Vec::new();
    let mut steps = 0;
    while c.busy() {
        c.step().unwrap();
        steps += 1;
        assert!(steps < 100_000, "fan-out did not drain");
        for (g, ids) in &groups {
            if winners.contains_key(g) {
                continue;
            }
            let Some(w) = ids.iter().copied().find(|id| c.finished(*id).is_some())
            else {
                continue;
            };
            winners.insert(g.clone(), w);
            for id in ids {
                // A sibling may have finished naturally in the very same
                // step (early EOS); only in-flight losers are cancelled.
                if *id != w && c.finished(*id).is_none() {
                    c.cancel(*id).unwrap();
                    cancelled.push(*id);
                }
            }
        }
    }
    assert_eq!(winners.len(), n_groups, "every group needs a winner");
    for (g, ids) in &groups {
        let w = winners[g];
        for id in ids {
            let reason = c.finished(*id).expect("all variants terminal");
            if *id == w {
                assert_ne!(
                    reason,
                    FinishReason::Cancelled,
                    "group {g}: winner must finish naturally"
                );
            } else if cancelled.contains(id) {
                assert_eq!(
                    reason,
                    FinishReason::Cancelled,
                    "group {g}: cancelled loser {id} has the wrong reason"
                );
            }
        }
    }
    // The staggered budgets (winner 6 tokens, losers 36/66) make
    // mid-flight losers the overwhelming shape; an all-EOS-tie run
    // would leave nothing cancelled and prove nothing.
    assert!(
        !cancelled.is_empty(),
        "no loser was ever cancelled mid-flight"
    );
    assert_eq!(
        c.metrics
            .requests_cancelled
            .load(std::sync::atomic::Ordering::Relaxed),
        cancelled.len() as u64
    );
    c.check_kv_invariants().unwrap();
}

/// Rollback-correctness property test (server-side speculative
/// decoding, `rust/src/specdec/`): temperature-0 token streams must be
/// BYTE-IDENTICAL with `enable_spec_decode` on vs off across the
/// serving shapes that stress the accept/rollback path — prefix-cache
/// hits (an identical second wave admits as suffix fills), a mid-flight
/// cancel, preemption + replay under a tiny block pool, and injected
/// `exec` transient faults landing inside verify executions (absorbed
/// by the in-step retries; the health ladder must end clean, with every
/// demotion re-promoted).  Speculation changes execution granularity —
/// several tokens can land per step — so unlike the span-group overlay
/// it DOES change plans; what it must never change is a single output
/// token.
#[test]
fn spec_decode_serving_matches_oracle_across_shapes() {
    let dir = require_artifacts!();
    use std::sync::atomic::Ordering::Relaxed;
    let mut all: Vec<Vec<Vec<u32>>> = Vec::new();
    let mut verified_seen = false;
    for enable_spec in [false, true] {
        let mut outputs: Vec<Vec<u32>> = Vec::new();

        // Scenario 1: repetitive greedy burst, then an identical second
        // wave (prefix-cache suffix fills over drafter-friendly
        // prompts), plus a mid-flight cancel.  The cancelled request is
        // NOT compared: with spec on, more tokens exist by the fixed
        // cancel step — by design.  Everything else must match.
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_spec_decode = enable_spec;
            cfg.prefill_chunk_tokens = 8;
            cfg.step_token_budget = 48;
            cfg.kv_block_tokens = 8;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let vocab = c.engine().config().vocab_size as u32;
            let wave = firstlayer::simtraffic::spec_workload(4, 3, 18, 24, vocab, 0x51);
            let first: Vec<u64> = wave
                .iter()
                .cloned()
                .map(|r| c.submit(r).unwrap())
                .collect();
            for _ in 0..3 {
                c.step().unwrap();
            }
            let second: Vec<u64> = wave
                .iter()
                .cloned()
                .map(|r| c.submit(r).unwrap())
                .collect();
            c.step().unwrap();
            c.cancel(first[2]).unwrap();
            c.run_to_completion(50_000).unwrap();
            assert_eq!(c.finished(first[2]), Some(FinishReason::Cancelled));
            for id in first.iter().chain(&second) {
                if *id != first[2] {
                    outputs.push(c.generated(*id).unwrap().to_vec());
                }
            }
            if enable_spec {
                verified_seen |= c.metrics.spec_executions.load(Relaxed) > 0;
            } else {
                assert_eq!(
                    c.metrics.spec_executions.load(Relaxed),
                    0,
                    "verifies executed with the knob off"
                );
            }
            c.check_kv_invariants().unwrap();
        }

        // Scenario 2: tiny pool -> preemption mid-generation + replay.
        // Spec shifts WHERE the pressure lands (tokens arrive in
        // accepted bursts), but replay recomputes identical KV, so the
        // streams cannot move.
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_spec_decode = enable_spec;
            cfg.prefill_chunk_tokens = 8;
            cfg.step_token_budget = 32;
            cfg.kv_blocks = 8;
            cfg.kv_block_tokens = 16;
            cfg.max_batch = 4;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let vocab = c.engine().config().vocab_size as u32;
            let reqs = firstlayer::simtraffic::spec_workload(4, 3, 16, 20, vocab, 0x52);
            let ids: Vec<u64> = reqs
                .into_iter()
                .map(|r| c.submit(r).unwrap())
                .collect();
            c.run_to_completion(50_000).unwrap();
            assert!(
                c.metrics.preemptions.load(Relaxed) > 0,
                "scenario must exercise preemption (spec={enable_spec})"
            );
            for id in &ids {
                outputs.push(c.generated(*id).unwrap().to_vec());
            }
            c.check_kv_invariants().unwrap();
        }

        // Scenario 3: transient `exec` faults land inside the busy
        // phase — including verify executions when spec is on.  The
        // counts are retry-absorbable, so no request may error and no
        // stream may move; a follow-up clean wave then gives any
        // demotion its cooldown steps, after which the ladder must be
        // fully re-promoted.
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_spec_decode = enable_spec;
            cfg.prefill_chunk_tokens = 8;
            cfg.step_token_budget = 48;
            cfg.fault_spec = "exec:transient:after=10:every=9:count=3".into();
            cfg.retry_max = 2;
            cfg.health_cooldown_steps = 8;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let vocab = c.engine().config().vocab_size as u32;
            let reqs = firstlayer::simtraffic::spec_workload(4, 3, 16, 24, vocab, 0x53);
            let ids: Vec<u64> = reqs
                .into_iter()
                .map(|r| c.submit(r).unwrap())
                .collect();
            c.run_to_completion(50_000).unwrap();
            assert!(
                c.metrics.fault_injected.load(Relaxed) > 0,
                "fault plan never fired (spec={enable_spec})"
            );
            for id in &ids {
                let reason = c.finished(*id).expect("terminal under faults");
                assert_ne!(
                    reason,
                    FinishReason::Error,
                    "retry-absorbable faults must not kill requests \
                     (spec={enable_spec})"
                );
                outputs.push(c.generated(*id).unwrap().to_vec());
            }
            let follow = firstlayer::simtraffic::spec_workload(2, 3, 16, 24, vocab, 0x54);
            let fids: Vec<u64> = follow
                .into_iter()
                .map(|r| c.submit(r).unwrap())
                .collect();
            c.run_to_completion(50_000).unwrap();
            for id in &fids {
                outputs.push(c.generated(*id).unwrap().to_vec());
            }
            let health = c.engine().health();
            for p in firstlayer::faults::PathId::ALL {
                assert!(
                    health.demotions(p) <= health.promotions(p),
                    "path {} left demoted after the cooldown (spec={enable_spec})",
                    p.label()
                );
            }
            c.check_kv_invariants().unwrap();
        }

        all.push(outputs);
    }
    assert_eq!(
        all[0], all[1],
        "speculative serving diverged from the plain-decode oracle at \
         temperature 0"
    );
    // When the bundle compiles >= 2-token span tiles, the workload must
    // have actually verified drafts (otherwise the equality is vacuous).
    let (_rt, eng) = engine(&dir, "tiny-serial");
    if eng.max_span_bucket(StepPath::Precompute) >= 2 {
        assert!(
            verified_seen,
            "spec-capable bundle but no verify was ever executed"
        );
    }
}

/// Device-resident vs legacy host KV must be temperature-0
/// TOKEN-IDENTICAL end to end across the three serving shapes that
/// exercise every sync point: chunked prefill (span sessions), KV
/// pressure with preemption + requeue (session writeback and replay),
/// and a prefix-cache hit served as a suffix-only span fill.
#[test]
fn device_resident_kv_matches_host_path() {
    let dir = require_artifacts!();
    let mut all: Vec<Vec<Vec<u32>>> = Vec::new();
    for enable_device in [false, true] {
        let mut outputs: Vec<Vec<u32>> = Vec::new();

        // Scenario 1: chunked prefill + steady-state decode batches.
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_device_kv = enable_device;
            cfg.prefill_chunk_tokens = 8;
            cfg.step_token_budget = 16;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let prompts: Vec<Vec<u32>> = vec![
                vec![3; 24],
                (0..21).map(|i| (i * 7 % 500) as u32).collect(),
                vec![2],
            ];
            let ids: Vec<u64> = prompts
                .iter()
                .map(|p| c.submit(Request::from_tokens(p.clone(), 10)).unwrap())
                .collect();
            // Step manually so a live device session is observable, and
            // guard against the device path silently regressing to the
            // host fallback: either sessions formed, or the engine
            // explicitly reports itself host-sticky.
            let mut saw_session = false;
            let mut steps = 0;
            while c.busy() {
                c.step().unwrap();
                saw_session |= c.device_session_active();
                steps += 1;
                assert!(steps < 50_000, "did not drain");
            }
            if enable_device {
                use std::sync::atomic::Ordering::Relaxed;
                assert!(
                    (saw_session && c.metrics.kv_sessions.load(Relaxed) > 0)
                        || !c.engine().device_kv_active(),
                    "no device session formed, yet the engine claims the \
                     device path is healthy (silent host fallback)"
                );
            } else {
                assert!(!saw_session, "host-only run built a device session");
            }
            for id in &ids {
                outputs.push(c.generated(*id).unwrap().to_vec());
            }
        }

        // Scenario 2: tiny pool -> preemption mid-generation, requeue,
        // replay (session rows dropped for victims, synced for others).
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_device_kv = enable_device;
            cfg.kv_blocks = 8;
            cfg.kv_block_tokens = 16;
            cfg.max_batch = 4;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let ids: Vec<u64> = (0..4)
                .map(|i| {
                    c.submit(Request::from_tokens(vec![2 + i as u32 * 3; 20], 24))
                        .unwrap()
                })
                .collect();
            c.run_to_completion(20_000).unwrap();
            assert!(
                c.metrics
                    .preemptions
                    .load(std::sync::atomic::Ordering::Relaxed)
                    > 0,
                "scenario must exercise preemption (device={enable_device})"
            );
            for id in &ids {
                outputs.push(c.generated(*id).unwrap().to_vec());
            }
        }

        // Scenario 3: prefix-cache hit -> suffix-only span fill.
        {
            let mut cfg = serving(&dir, "tiny-serial", true);
            cfg.enable_device_kv = enable_device;
            cfg.enable_prefix_cache = true;
            cfg.kv_block_tokens = 8;
            cfg.prefill_chunk_tokens = 8;
            cfg.step_token_budget = 16;
            let mut c = Coordinator::from_config(&cfg).unwrap();
            let system: Vec<u32> = (0..24).map(|i| (i * 13 % 500) as u32).collect();
            for suffix in [&[7u32, 9, 11][..], &[401, 3, 77, 12][..]] {
                let mut p = system.clone();
                p.extend_from_slice(suffix);
                let id = c.submit(Request::from_tokens(p, 8)).unwrap();
                c.run_to_completion(50_000).unwrap();
                outputs.push(c.generated(id).unwrap().to_vec());
            }
            assert!(
                c.metrics
                    .prefix_hits
                    .load(std::sync::atomic::Ordering::Relaxed)
                    >= 1,
                "scenario must exercise a prefix-cache hit (device={enable_device})"
            );
        }

        all.push(outputs);
    }
    assert_eq!(
        all[0], all[1],
        "device-resident KV diverges from the legacy host path at temperature 0"
    );
}

/// Admission control: once `max_waiting` requests queue up, further
/// submits bounce with `Error::Backpressure` — and the engine still
/// drains everything it accepted.
#[test]
fn backpressure_rejects_then_drains() {
    let dir = require_artifacts!();
    let mut cfg = serving(&dir, "tiny-serial", true);
    cfg.max_waiting = 2;
    let mut c = Coordinator::from_config(&cfg).unwrap();
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for i in 0..5u32 {
        let r = c.submit(Request::from_tokens(vec![4 + i; 6], 4));
        match r {
            Ok(id) => accepted.push(id),
            Err(firstlayer::Error::Backpressure(_)) => rejected += 1,
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert_eq!(accepted.len(), 2);
    assert_eq!(rejected, 3);
    assert_eq!(
        c.metrics
            .requests_rejected
            .load(std::sync::atomic::Ordering::Relaxed),
        3
    );
    c.run_to_completion(10_000).unwrap();
    for id in accepted {
        assert!(c.finished(id).is_some());
    }
}

/// KV pressure: a tiny block pool forces preemption mid-generation; the
/// preempted request must still complete with the right token count.
#[test]
fn preemption_recovers_and_completes() {
    let dir = require_artifacts!();
    let mut cfg = serving(&dir, "tiny-serial", true);
    cfg.kv_blocks = 8; // 8 blocks * 16 tokens: room for ~2 sequences
    cfg.kv_block_tokens = 16;
    cfg.max_batch = 4;
    let mut c = Coordinator::from_config(&cfg).unwrap();
    let ids: Vec<u64> = (0..4)
        .map(|i| {
            c.submit(Request::from_tokens(vec![2 + i as u32 * 3; 20], 24))
                .unwrap()
        })
        .collect();
    c.run_to_completion(20_000).unwrap();
    for id in ids {
        let got = c.generated(id).unwrap().len();
        assert!(
            got == 24 || c.finished(id).is_some(),
            "req {id}: incomplete ({got} tokens)"
        );
    }
    // The pool was small enough that at least one preemption should have
    // happened (not guaranteed by spec, but with these sizes it is).
    let preempts = c
        .metrics
        .preemptions
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(preempts > 0, "expected KV pressure to trigger preemption");
}

/// Priority classes: an interactive request admitted later still finishes
/// no later than batch-class requests submitted first (single-slot batch).
#[test]
fn interactive_priority_served_first() {
    let dir = require_artifacts!();
    let mut cfg = serving(&dir, "tiny-serial", true);
    cfg.max_batch = 1;
    cfg.max_admit_per_step = 1;
    let mut c = Coordinator::from_config(&cfg).unwrap();
    let slow = c
        .submit(Request::from_tokens(vec![5; 4], 8).with_priority(Priority::Batch))
        .unwrap();
    let fast = c
        .submit(
            Request::from_tokens(vec![9; 4], 8).with_priority(Priority::Interactive),
        )
        .unwrap();
    // Step until the interactive one finishes; the batch one must not have
    // produced more tokens than it.
    let mut steps = 0;
    while c.finished(fast).is_none() && steps < 1000 {
        c.step().unwrap();
        steps += 1;
    }
    assert!(c.finished(fast).is_some());
    assert!(
        c.generated(slow).unwrap_or(&[]).len() <= c.generated(fast).unwrap().len(),
        "batch-class request overtook the interactive one"
    );
    c.run_to_completion(10_000).unwrap();
}

/// `build_table` (PJRT re-derivation) reproduces the shipped table.  The
/// two compiler stacks (jax CPU jit vs xla_extension 0.5.1) need not be
/// bit-identical, but must agree to f32 accumulation noise.
#[test]
fn table_rebuild_matches_shipped() {
    let dir = require_artifacts!();
    for model in ["tiny-serial", "tiny-parallel"] {
        let (_rt, eng) = engine(&dir, model);
        let rebuilt = eng.build_table().unwrap();
        let diff = firstlayer::precompute::max_abs_diff(&rebuilt, eng.table()).unwrap();
        assert!(
            diff < 1e-4,
            "{model}: rebuilt table differs from shipped (max {diff})"
        );
    }
}

/// Traffic accounting: measured counters equal the analytical model for the
/// executed step sequence (E3's core assertion).
#[test]
fn traffic_counters_match_costmodel() {
    let dir = require_artifacts!();
    let (_rt, eng) = engine(&dir, "tiny-serial");
    let cfg = eng.config().clone();
    eng.traffic.reset();
    let caches = CacheBatch::zeros(
        cfg.n_layers,
        eng.decode_bucket(2, StepPath::Baseline).unwrap(),
        cfg.max_seq,
        cfg.n_kv_heads,
        cfg.head_dim(),
    );
    for _ in 0..3 {
        eng.decode(StepPath::Baseline, &[1, 2], &[0, 0], &caches)
            .unwrap();
        eng.decode(StepPath::Precompute, &[1, 2], &[0, 0], &caches)
            .unwrap();
    }
    let t = eng.traffic.snapshot();
    use firstlayer::costmodel;
    assert_eq!(t.l1_reads_baseline, 3 * costmodel::reads_without(&cfg, 2));
    assert_eq!(t.l1_reads_precomp, 3 * costmodel::reads_with(&cfg, 2));
    assert_eq!(t.table_bytes_read, t.l1_reads_precomp * 4);
}

/// The abs-PE model must refuse the precompute path end to end.
#[test]
fn abspe_model_rejects_precompute() {
    let dir = require_artifacts!();
    // tiny-abspe has no artifacts (it exists for the negative config test),
    // so exercise the engine guard directly on a rope model by forging the
    // config check at the coordinator level instead.
    let cfg = serving(&dir, "tiny-serial", true);
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let eng = Arc::new(ModelEngine::load(&rt, &manifest, &cfg.model).unwrap());
    // Engine-level: precompute on a non-rope config errors (simulated by
    // checking the error text path exists for PrecomputeGather with rope ok).
    assert!(eng.config().rope);
    // Coordinator-level: constructing with a fake non-rope name fails early.
    let mut bad = cfg.clone();
    bad.model = "tiny-abspe".to_string();
    assert!(Coordinator::from_config(&bad).is_err());
}

/// Server round-trip over a real TCP socket.
#[test]
fn server_tcp_roundtrip() {
    let dir = require_artifacts!();
    use std::io::{BufRead, BufReader, Write};
    let cfg = serving(&dir, "tiny-serial", true);
    let addr = "127.0.0.1:7911";
    std::thread::spawn(move || {
        let server = firstlayer::server::Server::new(addr);
        let _ = server.run(move || Coordinator::from_config(&cfg));
    });
    // Wait for the port to open.
    let mut stream = None;
    for _ in 0..100 {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(100)),
        }
    }
    let mut stream = stream.expect("server did not come up");
    stream
        .write_all(b"{\"op\":\"ping\"}\n{\"op\":\"generate\",\"prompt\":\"the quick\",\"max_new_tokens\":4}\n")
        .unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    let mut tokens = 0;
    let mut done = false;
    let mut pong = false;
    for line in reader.lines() {
        let line = line.unwrap();
        let v = firstlayer::util::json::parse(&line).unwrap();
        match v.get_opt("event").and_then(|e| e.as_str()) {
            Some("pong") => pong = true,
            Some("token") => tokens += 1,
            Some("done") => {
                done = true;
                break;
            }
            other => panic!("unexpected event {other:?} in {line}"),
        }
    }
    assert!(pong, "no pong");
    assert!(done, "no done event");
    assert_eq!(tokens, 4);
    // Metrics query on a fresh connection.
    let mut m = std::net::TcpStream::connect(addr).unwrap();
    m.write_all(b"{\"op\":\"traffic\"}\n").unwrap();
    let mut line = String::new();
    BufReader::new(m).read_line(&mut line).unwrap();
    let v = firstlayer::util::json::parse(&line).unwrap();
    assert!(v.get_opt("l1_reads_precomp").is_some());
}

/// `Coordinator::cancel` mid-generation: the cancelled request's blocks
/// all return to the pool (partition invariant holds), a terminal
/// `cancelled` finish is reported exactly once, and the surviving
/// stream's output is token-identical to a run without the cancelled
/// neighbor (temperature 0).
#[test]
fn cancel_frees_kv_and_leaves_others_untouched() {
    let dir = require_artifacts!();
    let solo = {
        let mut cfg = serving(&dir, "tiny-serial", true);
        cfg.enable_prefix_cache = false;
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let b = c.submit(Request::from_tokens(vec![9; 6], 12)).unwrap();
        c.run_to_completion(10_000).unwrap();
        c.generated(b).unwrap().to_vec()
    };
    let mut cfg = serving(&dir, "tiny-serial", true);
    cfg.enable_prefix_cache = false;
    let mut c = Coordinator::from_config(&cfg).unwrap();
    let total_free = c.kv_free_blocks();
    let a = c.submit(Request::from_tokens(vec![5; 8], 40)).unwrap();
    let b = c.submit(Request::from_tokens(vec![9; 6], 12)).unwrap();
    // Step until A is mid-generation (device decode sessions live on
    // this path when enabled), then cancel it.
    let mut steps = 0;
    while c.generated(a).map_or(0, |g| g.len()) < 3 {
        c.step().unwrap();
        steps += 1;
        assert!(steps < 10_000, "request A never started generating");
    }
    c.cancel(a).unwrap();
    assert_eq!(c.finished(a), Some(FinishReason::Cancelled));
    let evs = c.take_events();
    assert!(
        evs.iter().any(|e| matches!(
            e,
            firstlayer::coordinator::Event::Finished {
                id,
                reason: FinishReason::Cancelled,
            } if *id == a
        )),
        "no terminal cancelled event for A"
    );
    // Cancelling twice is an error, not a double free.
    assert!(c.cancel(a).is_err());
    c.run_to_completion(10_000).unwrap();
    assert_eq!(
        c.generated(b).unwrap(),
        &solo[..],
        "survivor stream perturbed by the cancel"
    );
    assert_eq!(
        c.kv_free_blocks(),
        total_free,
        "cancelled request leaked KV blocks"
    );
    c.check_kv_invariants().unwrap();
    assert_eq!(
        c.metrics
            .requests_cancelled
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );
}

/// A 3-turn chat session: each turn's prompt is the prior transcript
/// plus the new user delta, and the prior transcript — generated spans
/// included — is served from the prefix cache rather than re-prefilled.
/// `prefix_cached_tokens` must grow by (block-aligned) transcript spans
/// and the executed prefill must be exactly the uncached suffix.
#[test]
fn chat_three_turns_reuse_prior_transcript() {
    let dir = require_artifacts!();
    use std::sync::atomic::Ordering::Relaxed;
    let mut cfg = serving(&dir, "tiny-serial", true);
    cfg.enable_prefix_cache = true;
    cfg.kv_block_tokens = 4;
    cfg.prefill_chunk_tokens = 4;
    cfg.step_token_budget = 16;
    let mut c = Coordinator::from_config(&cfg).unwrap();
    let conv = c.chat_open().unwrap();
    let turns = ["the quick brown fox", " jumps over", " the lazy dog"];
    let mut prev_transcript_len = 0usize;
    for (i, t) in turns.iter().enumerate() {
        let delta_tokens = c.tokenizer.encode(t).len();
        let cached_before = c.metrics.prefix_cached_tokens.load(Relaxed);
        let prefill_before = c.engine().traffic.snapshot().prefill_tokens;
        let id = c.submit(Request::turn(conv, *t, 6)).unwrap();
        c.run_to_completion(50_000).unwrap();
        assert!(c.finished(id).is_some(), "turn {i} did not finish");
        let cached =
            (c.metrics.prefix_cached_tokens.load(Relaxed) - cached_before) as usize;
        let prefilled =
            (c.engine().traffic.snapshot().prefill_tokens - prefill_before) as usize;
        let prompt_len = if i == 0 {
            1 + delta_tokens // BOS
        } else {
            prev_transcript_len + delta_tokens
        };
        if i == 0 {
            assert_eq!(cached, 0, "first turn must be cold");
        } else {
            // At least one 4-token block, block-aligned, and within one
            // block of the full prior transcript (its newest token has
            // no KV row, so the last partial block stays uncached).
            assert!(cached >= 4, "turn {i}: prior transcript not reused");
            assert_eq!(cached % 4, 0, "turn {i}: cache reuse not block-aligned");
            assert!(
                cached + 4 > prev_transcript_len.saturating_sub(1),
                "turn {i}: cache served only {cached} of ~{prev_transcript_len} \
                 transcript tokens"
            );
        }
        assert_eq!(
            prefilled,
            prompt_len - cached,
            "turn {i}: executed prefill is not exactly the uncached suffix"
        );
        let tr = c.chat_transcript(conv).unwrap();
        assert!(tr.len() >= prompt_len, "turn {i}: transcript shrank");
        prev_transcript_len = tr.len();
    }
    assert_eq!(c.metrics.chat_turns.load(Relaxed), 3);
    assert!(c.metrics.chat_reused_tokens.load(Relaxed) > 0);
    c.chat_close(conv).unwrap();
    assert_eq!(c.chat_count(), 0);
    c.check_kv_invariants().unwrap();
}

/// Stop sequences: a second identical greedy request with a stop string
/// drawn from the first run's decoded output finishes early with reason
/// `stop`, and its stream is a prefix of the unconstrained one.
#[test]
fn stop_sequence_finishes_with_stop_reason() {
    let dir = require_artifacts!();
    let cfg = serving(&dir, "tiny-serial", true);
    let mut c = Coordinator::from_config(&cfg).unwrap();
    let free = c.submit(Request::from_tokens(vec![7; 5], 12)).unwrap();
    c.run_to_completion(10_000).unwrap();
    let unconstrained = c.generated(free).unwrap().to_vec();
    // Use the first generated token with a non-empty piece as the stop
    // (earlier tokens decode to nothing, so the match fires exactly
    // there).
    let Some((pos, stop)) = unconstrained.iter().enumerate().find_map(|(i, t)| {
        let piece = c.tokenizer.decode(&[*t]);
        (!piece.is_empty()).then_some((i, piece))
    }) else {
        eprintln!("skipping: every generated piece decodes empty");
        return;
    };
    let stopped = c
        .submit(
            Request::from_tokens(vec![7; 5], 12).with_params(SamplingParams {
                stop: vec![stop.clone()],
                ..Default::default()
            }),
        )
        .unwrap();
    c.run_to_completion(10_000).unwrap();
    assert_eq!(c.finished(stopped), Some(FinishReason::Stop));
    let got = c.generated(stopped).unwrap();
    assert_eq!(got.len(), pos + 1, "must stop at the matching token");
    assert_eq!(got, &unconstrained[..pos + 1]);
}

/// Protocol v2 over a real socket: one connection runs four tagged
/// `generate`s whose token streams interleave; demultiplexing by tag
/// reconstructs exactly the sequential v1 (untagged) outputs at
/// temperature 0.  A tagged admission failure comes back as `rejected`
/// with the tag, and `cancel` aborts a long-running stream with reason
/// `cancelled` without perturbing the other in-flight streams.
#[test]
fn server_v2_interleaved_tagged_streams() {
    let dir = require_artifacts!();
    use std::collections::HashMap;
    use std::io::{BufRead, BufReader, Write};
    let cfg = serving(&dir, "tiny-serial", true);
    let addr = "127.0.0.1:7912";
    std::thread::spawn(move || {
        let server = firstlayer::server::Server::new(addr);
        let _ = server.run(move || Coordinator::from_config(&cfg));
    });
    let mut stream = None;
    for _ in 0..100 {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(100)),
        }
    }
    let mut stream = stream.expect("server did not come up");
    let prompts = ["the quick", "attention is", "memory bandwidth", "a cache"];
    let mut batch = String::new();
    for (i, p) in prompts.iter().enumerate() {
        batch.push_str(&format!(
            "{{\"op\":\"generate\",\"tag\":\"t{i}\",\"prompt\":\"{p}\",\"max_new_tokens\":5}}\n"
        ));
    }
    // Never admissible (budget exceeds the context): rejected, tag echoed.
    batch.push_str(
        "{\"op\":\"generate\",\"tag\":\"bad\",\"prompt\":\"x\",\"max_new_tokens\":10000}\n",
    );
    // A long-running stream, then its cancellation.
    batch.push_str(
        "{\"op\":\"generate\",\"tag\":\"victim\",\"prompt\":\"zzz\",\"max_new_tokens\":90}\n",
    );
    batch.push_str("{\"op\":\"cancel\",\"tag\":\"victim\"}\n");
    stream.write_all(batch.as_bytes()).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    let mut tokens: HashMap<String, Vec<u32>> = HashMap::new();
    let mut done: HashMap<String, String> = HashMap::new();
    let mut rejected_bad = false;
    let mut cancel_acked = false;
    let mut cancel_lost_race = false;
    let mut lines_seen = 0usize;
    for line in reader.lines() {
        let line = line.unwrap();
        lines_seen += 1;
        assert!(lines_seen < 10_000, "event flood");
        let v = firstlayer::util::json::parse(&line).unwrap();
        let tag = v
            .get_opt("tag")
            .and_then(|t| t.as_str())
            .unwrap_or("")
            .to_string();
        match v.get_opt("event").and_then(|e| e.as_str()) {
            Some("token") => {
                let t = v.get_opt("token").and_then(|t| t.as_usize()).unwrap();
                assert!(!tag.is_empty(), "tagged request emitted untagged token");
                tokens.entry(tag).or_default().push(t as u32);
            }
            Some("done") => {
                let reason = v
                    .get_opt("reason")
                    .and_then(|r| r.as_str())
                    .unwrap()
                    .to_string();
                done.insert(tag, reason);
            }
            Some("rejected") => {
                assert_eq!(tag, "bad", "only the oversized request may bounce");
                rejected_bad = true;
            }
            Some("ok") => {
                assert_eq!(
                    v.get_opt("op").and_then(|o| o.as_str()),
                    Some("cancel")
                );
                cancel_acked = true;
            }
            Some("error") => {
                // Only one benign race can produce an error here: the
                // victim finished naturally (e.g. greedy EOS) before the
                // cancel command was drained.
                assert_eq!(
                    v.get_opt("op").and_then(|o| o.as_str()),
                    Some("cancel"),
                    "unexpected error event: {line}"
                );
                cancel_acked = true;
                cancel_lost_race = true;
            }
            other => panic!("unexpected event {other:?} in {line}"),
        }
        if done.len() == 5 && rejected_bad && cancel_acked {
            break;
        }
    }
    if cancel_lost_race {
        assert!(
            done.contains_key("victim"),
            "victim neither finished nor was cancelled"
        );
    } else {
        assert_eq!(
            done.get("victim").map(String::as_str),
            Some("cancelled"),
            "cancelled stream must terminate with reason cancelled"
        );
    }
    drop(stream);
    // Sequential v1 (untagged) runs on fresh connections must match the
    // demultiplexed streams token for token.
    for (i, p) in prompts.iter().enumerate() {
        let mut s2 = std::net::TcpStream::connect(addr).unwrap();
        s2.write_all(
            format!("{{\"op\":\"generate\",\"prompt\":\"{p}\",\"max_new_tokens\":5}}\n")
                .as_bytes(),
        )
        .unwrap();
        let r2 = BufReader::new(s2.try_clone().unwrap());
        let mut seq_tokens = Vec::new();
        for line in r2.lines() {
            let line = line.unwrap();
            let v = firstlayer::util::json::parse(&line).unwrap();
            match v.get_opt("event").and_then(|e| e.as_str()) {
                Some("token") => seq_tokens.push(
                    v.get_opt("token").and_then(|t| t.as_usize()).unwrap() as u32,
                ),
                Some("done") => break,
                other => panic!("unexpected event {other:?} in {line}"),
            }
        }
        let key = format!("t{i}");
        assert_eq!(
            seq_tokens, tokens[&key],
            "stream {key} diverges from its sequential v1 run"
        );
    }
}

/// Protocol v2 chat ops over TCP: open → two blocking sends (the server
/// holds the transcript; the client never re-sends history) → metrics
/// reports the turns → close → a send on the closed conversation is
/// rejected.
#[test]
fn server_v2_chat_session() {
    let dir = require_artifacts!();
    use std::io::{BufRead, BufReader, Write};
    let cfg = serving(&dir, "tiny-serial", true);
    let addr = "127.0.0.1:7913";
    std::thread::spawn(move || {
        let server = firstlayer::server::Server::new(addr);
        let _ = server.run(move || Coordinator::from_config(&cfg));
    });
    let mut stream = None;
    for _ in 0..100 {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(100)),
        }
    }
    let mut stream = stream.expect("server did not come up");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    fn read_json(reader: &mut BufReader<std::net::TcpStream>) -> firstlayer::util::json::Value {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        firstlayer::util::json::parse(&line).unwrap()
    }
    stream.write_all(b"{\"op\":\"chat.open\"}\n").unwrap();
    let opened = read_json(&mut reader);
    assert_eq!(opened.get_opt("event").and_then(|e| e.as_str()), Some("chat.opened"));
    let conv = opened.get_opt("conv").and_then(|c| c.as_u64()).unwrap();
    for text in ["the quick brown", " fox jumps"] {
        stream
            .write_all(
                format!(
                    "{{\"op\":\"chat.send\",\"conv\":{conv},\"text\":\"{text}\",\"max_new_tokens\":4}}\n"
                )
                .as_bytes(),
            )
            .unwrap();
        let mut tokens = 0;
        loop {
            let v = read_json(&mut reader);
            match v.get_opt("event").and_then(|e| e.as_str()) {
                Some("token") => tokens += 1,
                Some("done") => break,
                other => panic!("unexpected chat event {other:?}"),
            }
        }
        assert!(tokens >= 1, "turn produced no tokens");
    }
    stream.write_all(b"{\"op\":\"metrics\"}\n").unwrap();
    let m = read_json(&mut reader);
    assert_eq!(m.get_opt("event").and_then(|e| e.as_str()), Some("metrics"));
    assert!(
        m.get_opt("chat_turns").and_then(|v| v.as_usize()).unwrap() >= 2,
        "metrics must report the chat turns"
    );
    stream
        .write_all(format!("{{\"op\":\"chat.close\",\"conv\":{conv}}}\n").as_bytes())
        .unwrap();
    let closed = read_json(&mut reader);
    assert_eq!(closed.get_opt("event").and_then(|e| e.as_str()), Some("chat.closed"));
    // A turn on the closed conversation bounces with a rejected event.
    stream
        .write_all(
            format!(
                "{{\"op\":\"chat.send\",\"conv\":{conv},\"text\":\"hi\",\"max_new_tokens\":4}}\n"
            )
            .as_bytes(),
        )
        .unwrap();
    let rej = read_json(&mut reader);
    assert_eq!(rej.get_opt("event").and_then(|e| e.as_str()), Some("rejected"));
}

/// `chat.open` is admission-controlled: past `max_conversations` it
/// refuses with backpressure, and closing a conversation frees a slot.
#[test]
fn chat_open_capped() {
    let dir = require_artifacts!();
    let mut cfg = serving(&dir, "tiny-serial", true);
    cfg.max_conversations = 2;
    let mut c = Coordinator::from_config(&cfg).unwrap();
    let a = c.chat_open().unwrap();
    let b = c.chat_open().unwrap();
    assert_ne!(a, b, "handles must be distinct");
    assert!(a > 0 && a < (1u64 << 53) && b < (1u64 << 53));
    assert!(matches!(
        c.chat_open(),
        Err(firstlayer::Error::Backpressure(_))
    ));
    c.chat_close(a).unwrap();
    assert!(c.chat_open().is_ok(), "closing must free a slot");
}

/// Tracing is a pure observer: the same temp-0 workload run with
/// `enable_trace` off and on produces identical per-request token
/// streams, finish reasons, and deterministic schedule counters — and
/// the disabled tracer records nothing at all.
#[test]
fn trace_on_off_pure_observer() {
    let dir = require_artifacts!();
    let run = |trace: bool| {
        let mut cfg = serving(&dir, "tiny-serial", true);
        cfg.enable_trace = trace;
        cfg.prefill_chunk_tokens = 16;
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let vocab = c.engine().config().vocab_size as u32;
        let reqs = firstlayer::simtraffic::mixed_workload(8, 20, 2, 40, 6, vocab, 0xCAFE);
        let ids: Vec<u64> = reqs.into_iter().map(|r| c.submit(r).unwrap()).collect();
        c.run_to_completion(10_000).unwrap();
        let streams: Vec<(Vec<u32>, FinishReason)> = ids
            .iter()
            .map(|id| (c.generated(*id).unwrap().to_vec(), c.finished(*id).unwrap()))
            .collect();
        use std::sync::atomic::Ordering::Relaxed;
        let m = &c.metrics;
        let counters = [
            m.requests_done.load(Relaxed),
            m.tokens_out.load(Relaxed),
            m.prefill_chunks.load(Relaxed),
            m.span_executions.load(Relaxed),
            m.span_batched_executions.load(Relaxed),
            m.span_fallbacks.load(Relaxed),
            m.preemptions.load(Relaxed),
        ];
        let tracer = c.tracer();
        let dump = tracer.dump_chrome();
        (
            streams,
            counters,
            tracer.completed_count(),
            tracer.steps_count(),
            dump,
        )
    };
    let (s_off, c_off, done_off, steps_off, dump_off) = run(false);
    let (s_on, c_on, done_on, steps_on, dump_on) = run(true);
    assert_eq!(s_off, s_on, "token streams must be identical with tracing on");
    assert_eq!(c_off, c_on, "schedule counters must be identical with tracing on");
    // Off: the tracer is inert — no requests, no engine steps, no events.
    assert_eq!(done_off, 0, "disabled tracer must retain no requests");
    assert_eq!(steps_off, 0, "disabled tracer must retain no engine steps");
    assert!(dump_off
        .get("traceEvents")
        .unwrap()
        .as_arr()
        .unwrap()
        .is_empty());
    // On: every finished request landed in the ring with engine windows.
    assert_eq!(done_on, s_on.len(), "every finished request must be retained");
    assert!(steps_on > 0, "engine windows must be recorded when tracing");
    let events = dump_on.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "trace dump must carry events");
    // Every retained request contributes a complete request span (ph "X"
    // on the requests track) — the Perfetto lifecycle reconstruction.
    let request_spans = events
        .iter()
        .filter(|e| {
            e.get_opt("name").and_then(|n| n.as_str()) == Some("request")
                && e.get_opt("ph").and_then(|p| p.as_str()) == Some("X")
        })
        .count();
    assert_eq!(request_spans, s_on.len());
}

/// The fault plane mirrors the tracer's observer discipline: compiled
/// in and even ARMED (with a plan whose warmup is never reached), it
/// must not perturb anything — identical token streams, finish
/// reasons, and schedule counters vs the disarmed run, and every
/// fault/health counter pinned at zero.
#[test]
fn fault_plane_off_is_pure_observer() {
    let dir = require_artifacts!();
    let run = |spec: &str| {
        let mut cfg = serving(&dir, "tiny-serial", true);
        cfg.prefill_chunk_tokens = 16;
        cfg.fault_spec = spec.to_string();
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let vocab = c.engine().config().vocab_size as u32;
        let reqs =
            firstlayer::simtraffic::fault_burst_workload(8, 16, 6, vocab, 0xFA17);
        let ids: Vec<u64> = reqs.into_iter().map(|r| c.submit(r).unwrap()).collect();
        c.run_to_completion(10_000).unwrap();
        let streams: Vec<(Vec<u32>, FinishReason)> = ids
            .iter()
            .map(|id| (c.generated(*id).unwrap().to_vec(), c.finished(*id).unwrap()))
            .collect();
        use std::sync::atomic::Ordering::Relaxed;
        let m = &c.metrics;
        let counters = [
            m.requests_done.load(Relaxed),
            m.tokens_out.load(Relaxed),
            m.prefill_chunks.load(Relaxed),
            m.span_executions.load(Relaxed),
            m.span_batched_executions.load(Relaxed),
            m.preemptions.load(Relaxed),
        ];
        let faults = [
            m.requests_errored.load(Relaxed),
            m.fault_injected.load(Relaxed),
            m.fault_retries.load(Relaxed),
            m.health_demotions.load(Relaxed),
            m.health_promotions.load(Relaxed),
        ];
        let armed = c.engine().faults().armed();
        (streams, counters, faults, armed)
    };
    let (s_off, c_off, f_off, armed_off) = run("");
    // Warmup of a billion crossings: armed, never fires.
    let (s_on, c_on, f_on, armed_on) = run("exec:transient:after=1000000000");
    assert!(!armed_off && armed_on, "arming state must reflect the spec");
    assert_eq!(s_off, s_on, "streams must be identical with the plane armed");
    assert_eq!(c_off, c_on, "schedule counters must be identical");
    assert_eq!(f_off, [0; 5], "disarmed plane must count nothing");
    assert_eq!(f_on, [0; 5], "a never-firing plan must count nothing");
}

/// Property-style fault audit: across a spread of deterministic fault
/// plans (transient and fatal, at every boundary class), every request
/// reaches a terminal event, kvcache lease/refcount invariants hold,
/// the block pool adds back up (free + prefix leases = pool — nothing
/// leaked by mid-flight failure paths), and surviving greedy streams
/// are identical to the fault-free oracle.
#[test]
fn injected_faults_preserve_kv_invariants() {
    let dir = require_artifacts!();
    let run = |spec: &str| {
        let mut cfg = serving(&dir, "tiny-serial", true);
        cfg.prefill_chunk_tokens = 16;
        cfg.fault_spec = spec.to_string();
        cfg.health_cooldown_steps = 4;
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let vocab = c.engine().config().vocab_size as u32;
        let reqs =
            firstlayer::simtraffic::fault_burst_workload(8, 16, 6, vocab, 0xFA17);
        let tagged: Vec<(String, u64)> = reqs
            .into_iter()
            .map(|r| {
                let tag = r.tag.clone().unwrap();
                (tag, c.submit(r).unwrap())
            })
            .collect();
        c.run_to_completion(10_000).unwrap();
        let streams: Vec<(String, Vec<u32>, Option<FinishReason>)> = tagged
            .iter()
            .map(|(t, id)| {
                (
                    t.clone(),
                    c.generated(*id).unwrap_or(&[]).to_vec(),
                    c.finished(*id),
                )
            })
            .collect();
        (c, streams)
    };
    let (_, oracle) = run("");
    let oracle: std::collections::HashMap<String, Vec<u32>> = oracle
        .into_iter()
        .map(|(t, toks, reason)| {
            assert!(matches!(reason, Some(r) if r != FinishReason::Error));
            (t, toks)
        })
        .collect();
    for spec in [
        "exec:transient:after=10:every=7:count=4",
        "readback:transient:after=4:every=9:count=3",
        "h2d:transient:after=6:every=5:count=4",
        "sync:fatal:after=1:count=1",
        "exec:fatal:after=25:count=1",
        "gather:fatal:after=12:count=2",
        "exec:transient:after=8:every=6:count=3;sync:fatal:after=2:count=1",
    ] {
        let (c, streams) = run(spec);
        let mut errored = 0;
        for (tag, toks, reason) in &streams {
            let r = reason.unwrap_or_else(|| {
                panic!("[{spec}] `{tag}` reached no terminal event")
            });
            if r == FinishReason::Error {
                errored += 1;
            } else {
                assert_eq!(
                    toks, &oracle[tag],
                    "[{spec}] survivor `{tag}` diverged from the oracle"
                );
            }
        }
        // Terminal failures must release everything they held.
        c.check_kv_invariants()
            .unwrap_or_else(|e| panic!("[{spec}] kv invariants: {e}"));
        let free = c.kv_free_blocks();
        let leased = c.prefix_cache_blocks_held();
        let pool = ServingConfig::default().kv_blocks;
        assert_eq!(
            free + leased,
            pool,
            "[{spec}] block leak with {errored} errored requests"
        );
        use std::sync::atomic::Ordering::Relaxed;
        let injected = c.metrics.fault_injected.load(Relaxed);
        assert!(injected > 0, "[{spec}] plan never fired — vacuous case");
        assert_eq!(c.metrics.requests_errored.load(Relaxed), errored as u64);
    }
}

/// `--conversation-ttl`: the sweep closes idle conversations (freeing
/// their transcript and cap slot), cancels a mid-flight turn exactly
/// like `chat.close`, and leaks nothing.
#[test]
fn conversation_ttl_expires_idle_chats() {
    let dir = require_artifacts!();
    let mut cfg = serving(&dir, "tiny-serial", true);
    // Wide enough that a turn on the tiny model can't expire mid-run
    // (step() sweeps too), narrow enough to test quickly.
    cfg.conversation_ttl_ms = 150;
    let mut c = Coordinator::from_config(&cfg).unwrap();
    // An idle conversation with a finished turn expires...
    let conv = c.chat_open().unwrap();
    c.submit(Request::turn(conv, "hello", 4)).unwrap();
    c.run_to_completion(10_000).unwrap();
    assert_eq!(c.chat_count(), 1);
    // ...but not before its TTL.
    assert_eq!(c.sweep_conversations().unwrap(), 0, "fresh chat must survive");
    std::thread::sleep(std::time::Duration::from_millis(200));
    assert_eq!(c.sweep_conversations().unwrap(), 1);
    assert_eq!(c.chat_count(), 0);
    assert!(
        c.chat_transcript(conv).is_none(),
        "expiry must drop the transcript"
    );
    // A conversation with an in-flight turn: the sweep cancels the turn.
    let conv2 = c.chat_open().unwrap();
    let id = c.submit(Request::turn(conv2, "a much longer turn", 64)).unwrap();
    c.step().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(200));
    assert_eq!(c.sweep_conversations().unwrap(), 1);
    c.run_to_completion(10_000).unwrap();
    assert_eq!(c.finished(id), Some(FinishReason::Cancelled));
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(c.metrics.conversations_expired.load(Relaxed), 2);
    c.check_kv_invariants().unwrap();
    assert_eq!(
        c.kv_free_blocks() + c.prefix_cache_blocks_held(),
        ServingConfig::default().kv_blocks,
        "expiry leaked KV blocks"
    );
}

/// The front-door overlay is pure: tenant-tagged submissions with the
/// fair-share and overload knobs OFF, and a tenant-tagged run with the
/// ladder armed but calm (pressure never trips it), both produce
/// greedy streams byte-identical to the untagged baseline.  Degrading
/// gracefully must cost nothing when there is nothing to degrade.
#[test]
fn overload_overlay_off_is_pure() {
    let dir = require_artifacts!();
    let prompts = [
        "the quick brown fox",
        "attention is",
        "memory bandwidth limits",
        "a",
    ];
    let mut outputs: Vec<Vec<Vec<u32>>> = Vec::new();
    // Arm 0: untagged baseline.  Arm 1: tenants tagged, knobs off.
    // Arm 2: tenants tagged, ladder armed (calm) + fair share on with a
    // single tenant (no peers to share against).
    for arm in 0..3u8 {
        let mut cfg = serving(&dir, "tiny-serial", true);
        if arm == 2 {
            cfg.enable_overload_ladder = true;
            cfg.enable_fair_share = true;
        }
        let mut c = Coordinator::from_config(&cfg).unwrap();
        let ids: Vec<u64> = prompts
            .iter()
            .map(|p| {
                let mut r = Request::from_text(*p, 12);
                if arm > 0 {
                    r = r.with_tenant(7);
                }
                c.submit(r).unwrap()
            })
            .collect();
        c.run_to_completion(10_000).unwrap();
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(c.metrics.requests_shed.load(Relaxed), 0, "arm {arm} shed");
        assert_eq!(c.shed_level(), 0, "arm {arm}: calm ladder must stay at 0");
        outputs.push(
            ids.iter()
                .map(|id| c.generated(*id).unwrap().to_vec())
                .collect(),
        );
    }
    assert_eq!(outputs[0], outputs[1], "tenant tags alone changed streams");
    assert_eq!(outputs[0], outputs[2], "calm overlay changed streams");
}

/// Conversation handles are tenant-scoped capabilities: a send or close
/// presenting the wrong tenant fails with the typed cross-tenant error
/// and perturbs nothing, while the owner keeps full use of the handle.
#[test]
fn cross_tenant_conversation_rejected() {
    let dir = require_artifacts!();
    let cfg = serving(&dir, "tiny-serial", true);
    let mut c = Coordinator::from_config(&cfg).unwrap();
    let conv = c.chat_open_for(7).unwrap();
    // Wrong tenant: typed error, counted as a rejection, nothing queued.
    let err = c
        .submit(Request::turn(conv, "hello", 4).with_tenant(8))
        .unwrap_err();
    assert!(
        matches!(err, firstlayer::Error::CrossTenant(_)),
        "expected CrossTenant, got: {err}"
    );
    assert!(matches!(
        c.chat_close_for(conv, 8).unwrap_err(),
        firstlayer::Error::CrossTenant(_)
    ));
    // The anonymous default tenant is a tenant like any other.
    let err = c.submit(Request::turn(conv, "hello", 4)).unwrap_err();
    assert!(matches!(err, firstlayer::Error::CrossTenant(_)));
    // The owner is unaffected by the failed probes.
    let id = c
        .submit(Request::turn(conv, "hello", 4).with_tenant(7))
        .unwrap();
    c.run_to_completion(10_000).unwrap();
    assert!(
        matches!(
            c.finished(id),
            Some(FinishReason::MaxTokens | FinishReason::Eos)
        ),
        "owner's turn must finish clean: {:?}",
        c.finished(id)
    );
    assert!(c.chat_transcript(conv).is_some());
    c.chat_close_for(conv, 7).unwrap();
    assert_eq!(c.chat_count(), 0);
    use std::sync::atomic::Ordering::Relaxed;
    // The two failed SUBMITS count as rejections (the failed close is
    // an op error, not a request).
    assert_eq!(
        c.metrics.requests_rejected.load(Relaxed),
        2,
        "cross-tenant submit probes count as rejections"
    );
    assert_eq!(c.metrics.requests_shed.load(Relaxed), 0);
}
