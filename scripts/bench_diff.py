#!/usr/bin/env python3
"""Diff two BENCH_engine.json files (scripts/bench_gate.sh output).

Usage: bench_diff.py [--gate] OLD.json NEW.json

Matches results by their "bench" name and prints the relative change of
every shared numeric field.  With ``--gate``, per-metric regression
thresholds apply and the script exits 1 on any breach — this is what
lets scripts/ci_gate.sh fail a run on a perf regression instead of only
narrating drift.

Threshold model (higher-is-worse metrics; decreases never fail):

* timing fields (``*_us``, ``*_ms``) — noisy on shared CI hosts, so the
  allowed relative increase is generous (default 50%);
* deterministic schedule counters (uploads / syncs / execs / executions
  / transfers / calls / steps per span or per run) — these count device
  executions and cache movements, which the engine schedules exactly;
  ANY increase is a real regression (1% tolerance for float formatting);
* byte counters (``*_bytes*``) — deterministic too, same tight bound;
* higher-is-BETTER ratios (``*_speedup``) gate on the opposite side: a
  relative DECREASE beyond the allowance fails (timing-derived, so the
  allowance is the generous one).

Fields matching none of the patterns are informational only.  Benches
that appear or disappear never gate (sections come and go with
artifacts present/absent).
"""

import fnmatch
import json
import sys

# (glob over field name, max allowed relative increase).  First match
# wins; order matters.  Counters before the generic byte/timing globs.
THRESHOLDS = [
    ("*uploads*", 0.01),
    ("*syncs*", 0.01),
    ("*execs*", 0.01),
    ("*executions*", 0.01),
    ("*transfers*", 0.01),
    ("*calls*", 0.01),
    ("*steps*", 0.01),
    ("*_bytes*", 0.01),
    ("*_us", 0.50),
    ("*_ms", 0.50),
]

# Higher-is-better fields: (glob, max allowed relative DECREASE).  The
# span-group speedup is a ratio of two timings, so it inherits the
# timing noise allowance.  The speculative accept rate is a ratio of
# deterministic counters on a deterministic greedy workload, so it gets
# a tight bound: a meaningful drop means the drafter or the
# verify/rollback loop regressed, not the host clock.  Interactive
# goodput under overload is deterministic token accounting on a seeded
# storm — a drop means the fair-share/shed path started starving
# interactive work, so it gates tightly too.
GAIN_THRESHOLDS = [
    ("*_speedup", 0.50),
    ("spec_accept_rate", 0.05),
    ("interactive_goodput_under_overload", 0.05),
]


def threshold_for(field):
    """(max relative increase, max relative decrease) — None = no gate."""
    for pat, t in THRESHOLDS:
        if fnmatch.fnmatch(field, pat):
            return (t, None)
    for pat, t in GAIN_THRESHOLDS:
        if fnmatch.fnmatch(field, pat):
            return (None, t)
    return None


def index(path):
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for r in doc.get("results", []):
        name = r.get("bench")
        if isinstance(name, str):
            out[name] = r
    return out


def main():
    args = [a for a in sys.argv[1:] if a != "--gate"]
    gate = "--gate" in sys.argv[1:]
    if len(args) != 2:
        print(__doc__.strip())
        return 2
    old, new = index(args[0]), index(args[1])
    names = sorted(set(old) | set(new))
    if not names:
        print("bench-diff: no results on either side")
        return 0
    breaches = []
    for name in names:
        if name not in old:
            print(f"  {name}: NEW (no previous run)")
            continue
        if name not in new:
            print(f"  {name}: GONE (present in previous run)")
            continue
        o, n = old[name], new[name]
        fields = sorted(
            k
            for k in set(o) & set(n)
            if k != "bench"
            and isinstance(o[k], (int, float))
            and isinstance(n[k], (int, float))
        )
        deltas = []
        for k in fields:
            ov, nv = float(o[k]), float(n[k])
            if ov == 0.0:
                change = "0->%+g" % nv if nv else "0"
                rel = float("inf") if nv > 0 else 0.0
            else:
                rel = (nv - ov) / ov
                change = "%+.1f%%" % (100.0 * rel)
            t = threshold_for(k)
            mark = ""
            if t is not None:
                up, down = t
                if up is not None and rel > up:
                    mark = " [REGRESSION]"
                    breaches.append((name, k, change, "+%.0f%%" % (up * 100)))
                elif down is not None and rel < -down:
                    mark = " [REGRESSION]"
                    breaches.append((name, k, change, "-%.0f%%" % (down * 100)))
            deltas.append(f"{k} {change}{mark}")
        print(f"  {name}: " + ("; ".join(deltas) if deltas else "no shared numeric fields"))
    if breaches:
        print(f"bench-diff: {len(breaches)} threshold breach(es):")
        for name, k, change, allowed in breaches:
            print(f"  {name}.{k}: {change} (allowed {allowed})")
        if gate:
            return 1
        print("bench-diff: (informational run — pass --gate to fail on these)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
