#!/usr/bin/env python3
"""Diff two BENCH_engine.json files (scripts/bench_gate.sh output).

Usage: bench_diff.py OLD.json NEW.json

Matches results by their "bench" name and prints the relative change of
every shared numeric field.  Purely informational (exit 0 unless the
files are unreadable): the CI gate surfaces drift, it does not judge it
— perf gating thresholds belong to a human reading the trajectory.
"""

import json
import sys


def index(path):
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for r in doc.get("results", []):
        name = r.get("bench")
        if isinstance(name, str):
            out[name] = r
    return out


def main():
    if len(sys.argv) != 3:
        print(__doc__.strip())
        return 2
    old, new = index(sys.argv[1]), index(sys.argv[2])
    names = sorted(set(old) | set(new))
    if not names:
        print("bench-diff: no results on either side")
        return 0
    for name in names:
        if name not in old:
            print(f"  {name}: NEW (no previous run)")
            continue
        if name not in new:
            print(f"  {name}: GONE (present in previous run)")
            continue
        o, n = old[name], new[name]
        fields = sorted(
            k
            for k in set(o) & set(n)
            if k != "bench"
            and isinstance(o[k], (int, float))
            and isinstance(n[k], (int, float))
        )
        deltas = []
        for k in fields:
            ov, nv = float(o[k]), float(n[k])
            if ov == 0.0:
                change = "0->%+g" % nv if nv else "0"
            else:
                change = "%+.1f%%" % (100.0 * (nv - ov) / ov)
            deltas.append(f"{k} {change}")
        print(f"  {name}: " + ("; ".join(deltas) if deltas else "no shared numeric fields"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
