#!/usr/bin/env bash
# Bench gate (CI-runnable): run the engine-facing benches and record the
# perf trajectory machine-readably.
#
#   1. `cargo bench --bench scheduler` — scheduler tick, chunked-prefill
#      mixing, prefix reuse, and the modeled device-resident KV cache
#      movement (all artifact-free, self-asserting);
#   2. `cargo bench --bench e2e_latency` — real-engine decode/prefill
#      latency plus the decode_span device-vs-host section with
#      upload/readback byte counts (skips cleanly without `make
#      artifacts`).
#
# Benches print `BENCHJSON {...}` lines; this script collects them into
# BENCH_engine.json at the repo root:
#
#   {"generated_at": "...", "results": [ {"bench": "...", ...}, ... ]}
#
# Usage: scripts/bench_gate.sh   (from anywhere inside the repo)
set -euo pipefail
cd "$(dirname "$0")/.."
out="BENCH_engine.json"
lines="$(mktemp)"
trap 'rm -f "$lines"' EXIT

run_bench() {
  local name="$1" log
  log="$(mktemp)"
  # The bench output stays visible; JSON lines are harvested from the log.
  (cd rust && cargo bench --bench "$name") | tee "$log"
  grep '^BENCHJSON ' "$log" | sed 's/^BENCHJSON //' >> "$lines" || true
  rm -f "$log"
}

run_bench scheduler
run_bench e2e_latency

{
  echo '{'
  echo "  \"generated_at\": \"$(date -u +%Y-%m-%dT%H:%M:%SZ)\","
  echo '  "results": ['
  # Comma-join the collected JSON objects (empty file -> empty array).
  sed '$!s/$/,/' "$lines" | sed 's/^/    /'
  echo '  ]'
  echo '}'
} > "$out"

echo "[bench-gate] wrote $out ($(wc -l < "$lines" | tr -d ' ') results)"
