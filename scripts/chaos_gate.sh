#!/usr/bin/env bash
# Chaos gate (CI-runnable): drive the three-phase fault-recovery audit
# (`firstlayer chaos`) through the real engine:
#
#   1. oracle   — a fault-free fault_burst_workload records every
#      stream's expected tokens;
#   2. faulted  — the same burst under a deterministic transient+fatal
#      fault plan (`--fault-spec`): every request must reach a terminal
#      event, surviving streams must be byte-identical to the oracle,
#      retries must stay within the per-fault bound, and the KV pool
#      must add back up (free + prefix leases = kv_blocks — no leak on
#      any failure path);
#   3. storm    — a mass-cancel burst on the SAME engine after the plan
#      exhausts: recovery must leak nothing and every path the ladder
#      demoted must have re-promoted (cooldown probes ran).
#
# The binary exits non-zero on any violation, so this gate is just
# build + invoke.  Needs the AOT artifact bundle
# (`rust/artifacts/manifest.json`); skips cleanly when it is missing so
# the gate works on a fresh checkout, same as the trace gate.
#
# Usage: scripts/chaos_gate.sh   (from anywhere inside the repo)
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f rust/artifacts/manifest.json ]; then
  echo "[chaos-gate] skipping: run \`make artifacts\` first"
  exit 0
fi

bin=rust/target/release/firstlayer
if [ ! -x "$bin" ]; then
  echo "[chaos-gate] building release binary"
  (cd rust && cargo build --release --quiet)
fi

echo "[chaos-gate] fault-injection + recovery audit"
"$bin" chaos --artifacts rust/artifacts

echo "[chaos-gate] OK"
