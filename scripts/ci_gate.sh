#!/usr/bin/env bash
# CI gate: the one entry point a CI job runs.  Chains every repo gate in
# fail-fast order, then records the perf trajectory:
#
#   1. release build                     (cargo build --release)
#   2. tier-1 tests                      (cargo test -q)
#   3. docs gate                         (scripts/docs_gate.sh)
#   4. lint gate                         (scripts/lint_gate.sh)
#   5. trace gate                        (scripts/trace_gate.sh — a
#      simtraffic burst with lifecycle tracing on, whose Chrome-trace
#      dump must validate: complete submit→finish span chain per
#      finished request, phase sums bounded by their parent span)
#   6. chaos gate                        (scripts/chaos_gate.sh — the
#      deterministic fault-injection audit: a fault-free oracle burst,
#      the same burst under a transient+fatal fault plan — every
#      request terminal, survivors oracle-identical, no KV leak — then
#      a mass-cancel storm with the ladder re-promoting every demoted
#      path)
#   7. spec gate                         (scripts/spec_gate.sh — the
#      speculative-decoding audit: a spec-off oracle burst, the same
#      burst with `--spec` on — streams byte-identical, and the mean
#      emitted tokens per verify execution must clear 1.5)
#   8. overload gate                     (scripts/overload_gate.sh — the
#      graceful-degradation audit: a noisy-neighbor burst under
#      per-tenant fair share, 2x arrival storms against the armed shed
#      ladder — Batch sheds at rung 2 with a retry hint, nothing
#      in-flight is dropped — then a calm recovery back to rung 0)
#   9. bench gate                        (scripts/bench_gate.sh →
#      BENCH_engine.json at the repo root) — and, when a previous
#      BENCH_engine.json exists, a per-bench numeric diff
#      (scripts/bench_diff.py --gate) that FAILS the run on a
#      per-metric threshold breach: deterministic schedule counters
#      (executions, uploads, syncs, bytes) tolerate no increase, timing
#      fields get a noise allowance.  Delete BENCH_engine.json to
#      re-baseline after an intentional perf change.
#
# Every PASSING run also appends its BENCH_engine.json to
# bench_history/ (timestamped, pruned to the newest 50) so the perf
# trajectory across CI runs survives re-baselining and can be plotted
# or bisected after the fact.  Set BENCH_ARTIFACT_DIR to additionally
# copy the trajectory (bench_history/ plus the latest
# BENCH_engine.json) there — the hook CI uses to publish perf artifacts
# outside the workspace.
#
# Usage: scripts/ci_gate.sh   (from anywhere inside the repo)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "[ci-gate] 1/9 cargo build --release"
(cd rust && cargo build --release)

echo "[ci-gate] 2/9 tier-1 tests (cargo test -q)"
(cd rust && cargo test -q)

echo "[ci-gate] 3/9 docs gate"
scripts/docs_gate.sh

echo "[ci-gate] 4/9 lint gate"
scripts/lint_gate.sh

echo "[ci-gate] 5/9 trace gate"
scripts/trace_gate.sh

echo "[ci-gate] 6/9 chaos gate"
scripts/chaos_gate.sh

echo "[ci-gate] 7/9 spec gate"
scripts/spec_gate.sh

echo "[ci-gate] 8/9 overload gate"
scripts/overload_gate.sh

echo "[ci-gate] 9/9 bench gate"
prev=""
if [ -f BENCH_engine.json ]; then
  prev="$(mktemp)"
  cp BENCH_engine.json "$prev"
fi
scripts/bench_gate.sh

if [ -n "$prev" ]; then
  echo "[ci-gate] bench diff vs previous BENCH_engine.json (gating)"
  if command -v python3 >/dev/null 2>&1; then
    if ! python3 scripts/bench_diff.py --gate "$prev" BENCH_engine.json; then
      echo "[ci-gate] FAIL: bench threshold regression (see breaches above)"
      # Keep the PRE-regression baseline: otherwise a re-run would diff
      # against the regressed numbers and silently ratchet them in.
      cp "$prev" BENCH_engine.json
      rm -f "$prev"
      exit 1
    fi
  else
    echo "[ci-gate] python3 unavailable; raw diff (not gated):"
    diff "$prev" BENCH_engine.json || true
  fi
  rm -f "$prev"
else
  echo "[ci-gate] no previous BENCH_engine.json — baseline recorded"
fi

# Bench trajectory: persist the passing run's numbers.  Only gated-OK
# results land here, so the history is a clean series even across
# intentional re-baselines (which only delete BENCH_engine.json).
if [ -f BENCH_engine.json ]; then
  mkdir -p bench_history
  cp BENCH_engine.json "bench_history/BENCH_engine.$(date -u +%Y%m%dT%H%M%SZ).json"
  ls -1t bench_history/BENCH_engine.*.json 2>/dev/null | tail -n +51 | xargs -r rm -f
  echo "[ci-gate] bench trajectory: $(ls -1 bench_history/BENCH_engine.*.json | wc -l | tr -d ' ') run(s) in bench_history/"
fi

# Artifact publication: when CI points BENCH_ARTIFACT_DIR at an upload
# staging directory, mirror the perf trajectory there — the latest
# gated BENCH_engine.json plus the pruned bench_history/ series.
if [ -n "${BENCH_ARTIFACT_DIR:-}" ]; then
  mkdir -p "$BENCH_ARTIFACT_DIR"
  if [ -f BENCH_engine.json ]; then
    cp BENCH_engine.json "$BENCH_ARTIFACT_DIR/BENCH_engine.json"
  fi
  if [ -d bench_history ]; then
    mkdir -p "$BENCH_ARTIFACT_DIR/bench_history"
    cp bench_history/BENCH_engine.*.json "$BENCH_ARTIFACT_DIR/bench_history/" 2>/dev/null || true
  fi
  echo "[ci-gate] bench artifacts copied to $BENCH_ARTIFACT_DIR"
fi

echo "[ci-gate] OK"
