#!/usr/bin/env bash
# Docs gate (CI-runnable):
#   1. rustdoc must build warning-free (doc comments are part of the API);
#   2. every file reference in ARCHITECTURE.md / docs/*.md must resolve,
#      and docs/protocol.md must cover the server's event vocabulary
#      (rust/tests/docs_refs.rs).
#
# Usage: scripts/docs_gate.sh   (from anywhere inside the repo)
set -euo pipefail
# The crate manifest lives under rust/ (CARGO_MANIFEST_DIR in the tests);
# cargo also finds a workspace manifest by walking up from there.
cd "$(dirname "$0")/../rust"

echo "[docs-gate] cargo doc --no-deps (warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "[docs-gate] checking doc file references"
cargo test -q --test docs_refs

echo "[docs-gate] OK"
