#!/usr/bin/env bash
# Lint gate (CI-runnable):
#   1. clippy over every target (lib, bins, tests, benches, examples)
#      with warnings promoted to errors;
#   2. rustfmt in check mode — formatting drift fails the gate.
#
# Usage: scripts/lint_gate.sh   (from anywhere inside the repo)
set -euo pipefail
# The crate manifest lives under rust/ (same layout as docs_gate.sh).
cd "$(dirname "$0")/../rust"

echo "[lint-gate] cargo clippy --all-targets (warnings are errors)"
cargo clippy --all-targets --quiet -- -D warnings

echo "[lint-gate] cargo fmt --check"
cargo fmt --check

echo "[lint-gate] OK"
