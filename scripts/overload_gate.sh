#!/usr/bin/env bash
# Overload gate (CI-runnable): drive the three-phase graceful-degradation
# audit (`firstlayer overload-smoke`) through the real engine:
#
#   1. fair share — a noisy-neighbor burst (one hog tenant flooding Batch
#      work over small interactive tenants) with per-tenant DRR on: every
#      bystander request must finish clean, no bystander tenant may fall
#      below the peer-group goodput floor, and interactive TTFT p99 must
#      stay bounded;
#   2. shed ladder — 2x arrival storms against the armed overload ladder
#      with a tight step budget: the ladder must actually trip, Batch
#      admission must shed at rung 2 with a `retry_after_ms` hint, and
#      every ADMITTED request must still reach a clean terminal event
#      (shedding is an admission decision, never an eviction);
#   3. recovery — a calm stretch after the storm must walk the ladder
#      back to rung 0 with demotions == promotions.
#
# The binary exits non-zero on any violation, so this gate is just
# build + invoke.  Needs the AOT artifact bundle
# (`rust/artifacts/manifest.json`); skips cleanly when it is missing so
# the gate works on a fresh checkout, same as the trace/chaos/spec gates.
#
# Usage: scripts/overload_gate.sh   (from anywhere inside the repo)
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f rust/artifacts/manifest.json ]; then
  echo "[overload-gate] skipping: run \`make artifacts\` first"
  exit 0
fi

bin=rust/target/release/firstlayer
if [ ! -x "$bin" ]; then
  echo "[overload-gate] building release binary"
  (cd rust && cargo build --release --quiet)
fi

echo "[overload-gate] fair share + shed ladder + recovery audit"
"$bin" overload-smoke --artifacts rust/artifacts

echo "[overload-gate] OK"
