#!/usr/bin/env bash
# Speculative-decoding gate (CI-runnable): drive the two-phase
# correctness + payoff audit (`firstlayer spec-smoke`) through the real
# engine:
#
#   1. oracle — a repetitive greedy spec_workload burst with speculation
#      OFF records every stream's expected tokens;
#   2. spec   — the same burst with `--spec` on: every stream must be
#      byte-identical to the oracle (accept/rollback is invisible in
#      output space), verifies must actually have executed, and the
#      mean emitted tokens per verify execution must clear the floor
#      (default 1.5) — one scored span execution has to replace more
#      than 1.5 plain decode steps on drafter-friendly traffic, or the
#      machinery is pure overhead.
#
# The binary exits non-zero on any violation, so this gate is just
# build + invoke.  Needs the AOT artifact bundle
# (`rust/artifacts/manifest.json`); skips cleanly when it is missing so
# the gate works on a fresh checkout, same as the trace and chaos gates.
#
# Usage: scripts/spec_gate.sh   (from anywhere inside the repo)
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f rust/artifacts/manifest.json ]; then
  echo "[spec-gate] skipping: run \`make artifacts\` first"
  exit 0
fi

bin=rust/target/release/firstlayer
if [ ! -x "$bin" ]; then
  echo "[spec-gate] building release binary"
  (cd rust && cargo build --release --quiet)
fi

echo "[spec-gate] speculative decoding: oracle equivalence + acceptance floor"
"$bin" spec-smoke --artifacts rust/artifacts --min-accept 1.5

echo "[spec-gate] OK"
