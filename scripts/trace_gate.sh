#!/usr/bin/env bash
# Trace gate (CI-runnable): drive a simtraffic burst through the engine
# with lifecycle tracing ON (`firstlayer trace-smoke`) and validate the
# dumped Chrome trace-event JSON:
#
#   1. the dump is well-formed JSON with a `traceEvents` array;
#   2. every finished request has a complete submit→finish span chain —
#      a `request` complete span (ph "X") with a terminal finish reason,
#      a `queue` span, and at least one execution child span, all nested
#      inside the request window;
#   3. per-phase engine timings never exceed their parent span
#      (`gather_us + h2d_us + exec_us + readback_us + sync_us <= dur`) —
#      the tracer's pending-absorption invariant.
#
# Needs the AOT artifact bundle (`rust/artifacts/manifest.json`); skips
# cleanly when it is missing so the gate works on a fresh checkout, same
# as the artifact-dependent benches and integration tests.
#
# Usage: scripts/trace_gate.sh   (from anywhere inside the repo)
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f rust/artifacts/manifest.json ]; then
  echo "[trace-gate] skipping: run \`make artifacts\` first"
  exit 0
fi

bin=rust/target/release/firstlayer
if [ ! -x "$bin" ]; then
  echo "[trace-gate] building release binary"
  (cd rust && cargo build --release --quiet)
fi

out="$(mktemp -d)"
trap 'rm -rf "$out"' EXIT

echo "[trace-gate] trace-smoke burst (tracing on)"
"$bin" trace-smoke --artifacts rust/artifacts --out "$out/trace.json" --requests 10

echo "[trace-gate] validating $out/trace.json"
python3 - "$out/trace.json" <<'PY'
import json
import sys

with open(sys.argv[1]) as f:
    dump = json.load(f)  # (1) must parse

events = dump["traceEvents"]
assert isinstance(events, list) and events, "empty traceEvents"
assert "dropped_requests" in dump, "missing dropped_requests"

PHASES = ("gather_us", "h2d_us", "exec_us", "readback_us", "sync_us")
EXEC_KINDS = {"prefill_chunk", "span_tile", "group_tile", "decode_step", "sync"}

# Index the pid-1 (requests) track by tid = request id.
by_req = {}
for e in events:
    if e.get("ph") in ("X", "i") and e.get("pid") == 1:
        by_req.setdefault(e["tid"], []).append(e)

finished = 0
for tid, evs in sorted(by_req.items()):
    req = [e for e in evs if e.get("name") == "request" and e["ph"] == "X"]
    assert len(req) == 1, f"request {tid}: {len(req)} request spans"
    req = req[0]
    reason = req["args"]["reason"]
    if reason == "live":
        continue  # still in flight at dump time: chain legitimately open
    finished += 1
    # (2) complete submit→finish chain.
    r0, r1 = req["ts"], req["ts"] + req["dur"]
    names = {e["name"] for e in evs}
    assert "queue" in names, f"request {tid}: no queue span"
    execs = [e for e in evs if e["ph"] == "X" and e["name"] in EXEC_KINDS]
    assert execs, f"request {tid}: finished with no execution spans"
    for e in evs:
        if e["ph"] != "X" or e is req:
            continue
        ts, dur = e["ts"], e.get("dur", 0)
        assert r0 <= ts and ts + dur <= r1, (
            f"request {tid}: span {e['name']} [{ts},{ts+dur}] "
            f"outside request window [{r0},{r1}]"
        )
        # (3) phase-sum invariant.
        args = e.get("args", {})
        phase_sum = sum(args.get(k, 0) for k in PHASES)
        assert phase_sum <= dur, (
            f"request {tid}: span {e['name']} phases {phase_sum}us > dur {dur}us"
        )

assert finished > 0, "no finished requests in the dump"

# The pid-2 engine track must carry execution steps with the phase-sum
# invariant too.
steps = [e for e in events if e.get("pid") == 2 and e.get("ph") == "X"]
assert steps, "no engine-track steps"
for e in steps:
    args = e.get("args", {})
    phase_sum = sum(args.get(k, 0) for k in PHASES)
    assert phase_sum <= e.get("dur", 0), (
        f"engine step {e['name']} phases {phase_sum}us > dur {e.get('dur')}us"
    )

print(
    f"[trace-gate] {finished} finished request chain(s), "
    f"{len(steps)} engine step(s), {len(events)} events: OK"
)
PY

echo "[trace-gate] OK"
